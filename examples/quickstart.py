"""Quickstart: one Montage workflow through KubeAdaptor + ARAS.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.engine import EngineConfig, KubeAdaptor
from repro.workflows.dags import montage


def main():
    engine = KubeAdaptor(EngineConfig())
    wf = montage("demo", np.random.default_rng(0))
    print(f"workflow: {wf.num_tasks} tasks, "
          f"critical path {wf.critical_path_length():.0f}s")
    engine.submit(wf, at=0.0)
    m = engine.run()

    print(f"makespan: {m.makespan/60:.2f} min")
    print(f"allocations: {m.num_allocations}, waits: {m.num_waits}")
    print("first allocations (time, task, cpu_m, mem_Mi, Alg.3 scenario):")
    for t, key, cpu, mem, scen in m.alloc_trace[:6]:
        print(f"  t={t:6.1f}s {key:22s} {cpu:7.1f}m {mem:7.1f}Mi {scen}")


if __name__ == "__main__":
    main()
