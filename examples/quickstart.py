"""Quickstart: one Montage workflow through the Scenario API.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Scenario, run_scenario


def main():
    scenario = Scenario(
        name="quickstart",
        workflows=("montage",),
        arrival="constant",
        arrival_params={"y": 1, "bursts": 1},
    )
    result = run_scenario(scenario)

    wf = result.num_workflows
    print(f"scenario: {scenario.name} ({wf} workflow, "
          f"{result.num_allocations} allocations, "
          f"{result.num_waits} waits)")
    print(f"makespan: {result.avg_total_duration/60:.2f} min, "
          f"usage cpu/mem {result.cpu_usage_rate:.0%}/"
          f"{result.mem_usage_rate:.0%}")
    print("first allocations (time, task, cpu_m, mem_Mi, Alg.3 scenario):")
    for t, key, cpu, mem, scen in result.metrics.alloc_trace[:6]:
        print(f"  t={t:6.1f}s {key:22s} {cpu:7.1f}m {mem:7.1f}Mi {scen}")
    print("as JSON:", result.to_json()[:120], "...")


if __name__ == "__main__":
    main()
