"""End-to-end training driver: a DAG of LM training jobs under ARAS.

Trains a reduced qwen2-family model for a few hundred steps with the full
stack (synthetic data pipeline, AdamW, async checkpointing, crash-restart)
while the ARAS control plane assigns each job its microbatch quota —
exactly the paper's vertical autoscaling applied to ML workloads.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--big]

--big uses a ~100M-parameter config (hours on 1 CPU core; the default
reduced config finishes in ~2 min and exercises the same code).
"""
import argparse
import dataclasses
import time

from repro.configs import get_config, get_smoke_config
from repro.engine.mljobs import MLTaskSpec, run_ml_workflow


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true",
                    help="~100M params (slow on CPU)")
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    if args.big:
        base = get_config("qwen2-0.5b")
        cfg = dataclasses.replace(
            base, num_layers=8, d_model=512, num_heads=8, num_kv_heads=2,
            head_dim=64, d_ff=2048, vocab_size=32000, remat=False,
            name="qwen2-100m")
    else:
        cfg = get_smoke_config("qwen2-0.5b")

    jobs = [
        MLTaskSpec("pretrain", cfg, steps=args.steps, batch=8,
                   seq=args.seq),
        MLTaskSpec("finetune", cfg, steps=max(20, args.steps // 4),
                   batch=8, seq=args.seq, depends_on=("pretrain",)),
    ]
    t0 = time.time()
    out = run_ml_workflow(jobs, cluster_mem=128.0)
    for tid, r in out.items():
        print(f"{tid:10s} batch={r.batch_used} restarts={r.restarts} "
              f"final_loss={r.final_loss:.4f} wall={r.wall_s:.1f}s")
    print(f"total {time.time()-t0:.1f}s — params: "
          f"{cfg.param_count()/1e6:.1f}M")


if __name__ == "__main__":
    main()
