"""Paper reproduction in one command: a Table-2 slice (LIGO, all three
arrival patterns, ARAS vs the FCFS baseline) as one declarative
Scenario-API sweep.

    PYTHONPATH=src python examples/paper_reproduction.py [--full]

--full runs the complete 4-workflow × 3-pattern matrix
(≈15 min on one core; this is what `python -m benchmarks.table2` does).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    if args.full:
        # The benchmarks package lives at the repo root, which is not on
        # sys.path when this file is run as a script.
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        from benchmarks import table2

        table2.main()
        return

    from repro.api import Scenario, grid, run_scenario

    base = Scenario(name="table2", workflows=("ligo",))
    sweep = grid(base, allocators=("aras", "fcfs"),
                 arrivals=("constant", "linear", "pyramid"))
    results = {(s.engine.alloc.algorithm, s.arrival): run_scenario(s)
               for s in sweep}

    print("LIGO workflows, ARAS vs FCFS (paper Table 2 slice):")
    for pat_name in ("constant", "linear", "pyramid"):
        a = results[("aras", pat_name)]
        f = results[("fcfs", pat_name)]
        print(f"  {pat_name:9s} total {a.avg_total_duration/60:6.2f}/"
              f"{f.avg_total_duration/60:6.2f} min "
              f"(-{100*(1-a.avg_total_duration/f.avg_total_duration):.1f}%)  "
              f"per-wf {a.avg_workflow_duration/60:5.2f}/"
              f"{f.avg_workflow_duration/60:5.2f} min "
              f"(-{100*(1-a.avg_workflow_duration/f.avg_workflow_duration):.1f}%)")


if __name__ == "__main__":
    main()
