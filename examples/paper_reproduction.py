"""Paper reproduction in one command: a Table-2 slice (LIGO, all three
arrival patterns) with ARAS vs the FCFS baseline.

    PYTHONPATH=src python examples/paper_reproduction.py [--full]

--full runs the complete 4-workflow × 3-pattern matrix
(≈15 min on one core; this is what `python -m benchmarks.table2` does).
"""
import argparse

from benchmarks import table2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    if args.full:
        table2.main()
        return

    from repro.engine import EngineConfig, run_experiment
    from repro.workflows.arrival import PATTERNS

    print("LIGO workflows, ARAS vs FCFS (paper Table 2 slice):")
    for pat_name, pat in PATTERNS.items():
        res = {}
        for alloc in ("aras", "fcfs"):
            m = run_experiment("ligo", pat(), alloc, seed=0,
                               config=EngineConfig())
            res[alloc] = m
        a, f = res["aras"], res["fcfs"]
        print(f"  {pat_name:9s} total {a.makespan/60:6.2f}/"
              f"{f.makespan/60:6.2f} min "
              f"(-{100*(1-a.makespan/f.makespan):.1f}%)  "
              f"per-wf {a.avg_workflow_duration/60:5.2f}/"
              f"{f.avg_workflow_duration/60:5.2f} min "
              f"(-{100*(1-a.avg_workflow_duration/f.avg_workflow_duration):.1f}%)")


if __name__ == "__main__":
    main()
