"""Fig. 9 reproduction: OOMKilled task pods + ARAS self-healing.

    PYTHONPATH=src python examples/oom_selfheal.py
"""
from repro.engine import EngineConfig, run_experiment


def main():
    # §6.2.2: min_mem declared far below what the task really touches.
    kw = dict(mem=2600.0, min_mem=200.0, actual_min_mem=2000.0)
    m = run_experiment("montage", [(0.0, 10)], "aras", seed=0,
                       config=EngineConfig(), task_kwargs=kw)
    print(f"OOMKilled events: {len(m.oom_events)}, "
          f"reallocations: {len(m.realloc_events)}")
    print("timeline (first 5):")
    for (t_oom, key), (t_re, _) in list(zip(m.oom_events,
                                            m.realloc_events))[:5]:
        print(f"  {key:28s} OOMKilled @{t_oom:7.1f}s -> "
              f"reallocated @{t_re:7.1f}s")
    print(f"all 10 workflows completed; makespan {m.makespan/60:.1f} min")


if __name__ == "__main__":
    main()
