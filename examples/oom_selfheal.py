"""Fig. 9 reproduction: OOMKilled task pods + ARAS self-healing.

    PYTHONPATH=src python examples/oom_selfheal.py

Part two injects a mid-run node crash (``repro.chaos``) on top of the
same workload: displaced pods re-enter admission through the HEAL path
and the run reports the recovery counters.
"""
import dataclasses

from repro.api import Scenario, run_scenario


def main():
    # §6.2.2: min_mem declared far below what the task really touches.
    scenario = Scenario(
        name="oom-selfheal",
        workflows=("montage",),
        arrival="constant",
        arrival_params={"y": 10, "bursts": 1},
        task_kwargs={"mem": 2600.0, "min_mem": 200.0,
                     "actual_min_mem": 2000.0},
    )
    result = run_scenario(scenario)
    m = result.metrics
    print(f"OOMKilled events: {result.num_oom_events}, "
          f"reallocations: {result.num_reallocations}")
    print("timeline (first 5):")
    for (t_oom, key), (t_re, _) in list(zip(m.oom_events,
                                            m.realloc_events))[:5]:
        print(f"  {key:28s} OOMKilled @{t_oom:7.1f}s -> "
              f"reallocated @{t_re:7.1f}s")
    print(f"all {result.num_workflows} workflows completed; "
          f"makespan {result.avg_total_duration/60:.1f} min")

    # Same workload, now losing two nodes mid-run: every displaced task
    # either recovers through HEAL or is terminally counted FAILED.
    chaos = dataclasses.replace(
        scenario, name="oom-selfheal+crash",
        engine=scenario.engine.evolve(
            fault_schedule="node_crash",
            fault_params={"at": 120.0, "nodes": 2}, fault_seed=1))
    cres = run_scenario(chaos)
    print(f"\nwith a 2-node crash at t=120s:")
    print(f"  displaced tasks:   {cres.num_displaced}")
    print(f"  recovered (HEAL):  {cres.num_recovered}")
    print(f"  failed tasks:      {cres.num_failed_tasks}, "
          f"failed workflows: {cres.num_failed_workflows}")
    print(f"  mean time to recovery: {cres.mean_time_to_recovery:.1f}s")
    print(f"  {cres.num_workflows} workflows still completed; "
          f"makespan {cres.avg_total_duration/60:.1f} min")


if __name__ == "__main__":
    main()
