"""End-to-end serving driver: continuous batching with batched requests.

Serves a small decoder LM: requests arrive in bursts, the engine admits
them into cache slots (prefill) and advances all active slots with one
batched decode step per iteration — the serving-side analogue of the
paper's high-concurrency task-pod scenario.

    PYTHONPATH=src python examples/serve_lm.py [--requests 12] [--slots 4]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.api import build_model
from repro.serving import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config("llama3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params,
                      ServeConfig(n_slots=args.slots, max_len=64))

    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(3, 10))
        rids.append(eng.submit(prompt, max_new_tokens=args.new_tokens))
    done = eng.run_to_completion()
    dt = time.time() - t0

    total_tokens = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests / {total_tokens} tokens in "
          f"{dt:.1f}s ({total_tokens/dt:.1f} tok/s, "
          f"{args.slots} slots, continuous batching)")
    for rid in rids[:3]:
        print(f"  request {rid}: {done[rid]}")


if __name__ == "__main__":
    main()
