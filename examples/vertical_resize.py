"""ARC-V vertical adaptivity: in-place resize vs kill-and-reallocate.

    PYTHONPATH=src python examples/vertical_resize.py

Part one replays the Fig. 9 under-declared-memory workload twice on the
same seeded trace: the baseline takes every OOMKill and pays the restart
penalty through reallocation; the vertical engine grows the doomed pod
in place (headroom permitting) and the task runs to its original
completion time.

Part two attaches a deterministic usage curve (``repro.vertical``) so
actual consumption decays below the admitted quota, and shows the
resize controller reclaiming that over-provisioned capacity for the
pending queue.
"""
import dataclasses

from repro.api import Scenario, run_scenario


def main():
    # §6.2.2: min_mem declared far below what the task really touches —
    # every task pod is admitted with a quota that undershoots its
    # runtime floor and is doomed to OOMKill.
    base = Scenario(
        name="oom-baseline",
        workflows=("montage",),
        arrival="constant",
        arrival_params={"y": 10, "bursts": 1},
        task_kwargs={"mem": 2600.0, "min_mem": 200.0,
                     "actual_min_mem": 2000.0},
    )
    kill = run_scenario(base)
    print("kill-and-reallocate (baseline):")
    print(f"  OOMKilled events:  {kill.num_oom_events}, "
          f"reallocations: {kill.num_reallocations}")
    print(f"  makespan {kill.avg_total_duration/60:.1f} min")

    grow = run_scenario(dataclasses.replace(
        base, name="oom-resize",
        engine=base.engine.evolve(vertical=True)))
    print("\nin-place grow (ARC-V, same seeded trace):")
    print(f"  OOMKilled events:  {grow.num_oom_events}, "
          f"resizes avoided an OOM: {grow.resizes_avoided_oom}")
    print(f"  makespan {grow.avg_total_duration/60:.1f} min "
          f"({kill.avg_total_duration - grow.avg_total_duration:.0f}s "
          f"saved, no restart penalty)")

    # Over-provisioned instead of under-: a ramp curve makes actual
    # usage decay from 90% to 20% of quota while the admitted request
    # stays flat.  The resize controller shrinks running pods to their
    # remaining-lifetime peak and the pending queue re-admits against
    # the reclaimed capacity.
    curved = Scenario(
        name="vertical-reclaim",
        workflows=("montage",),
        arrival="constant",
        arrival_params={"y": 4, "interval": 30.0, "bursts": 2},
        usage_curves={"montage": {"curve": "ramp",
                                  "params": {"start": 0.9, "end": 0.2}}},
        seed=3,
    )
    flat = run_scenario(curved)
    resz = run_scenario(dataclasses.replace(
        curved, engine=curved.engine.evolve(vertical=True,
                                            resize_interval=10.0)))
    print("\nover-provisioned ramp workload (usage 90% -> 20% of quota):")
    print(f"  resizes: {resz.num_resizes} "
          f"({resz.num_shrinks} shrinks, {resz.num_grows} grows)")
    print(f"  reclaimed: {resz.reclaimed_cpu_seconds:,.0f} cpu-s, "
          f"{resz.reclaimed_mem_seconds:,.0f} mem-s")
    print(f"  allocation waits: {flat.num_waits} -> {resz.num_waits}")
    print(f"  makespan: {flat.avg_total_duration/60:.1f} -> "
          f"{resz.avg_total_duration/60:.1f} min")


if __name__ == "__main__":
    main()
