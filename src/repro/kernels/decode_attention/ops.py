"""Public wrapper: padding + backend dispatch for flash decoding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array,
                 interpret=None) -> jax.Array:
    """q [B,H,d]; k/v [B,T,KV,d]; pos [B] -> [B,H,d]."""
    if interpret is None:
        interpret = not _on_tpu()
    B, H, d = q.shape
    T = k.shape[1]
    dp = (-d) % 128
    bk = min(256, 1 << (T - 1).bit_length())
    tp = (-T) % bk
    if dp or tp:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, dp)))
        k = jnp.pad(k, ((0, 0), (0, tp), (0, 0), (0, dp)))
        v = jnp.pad(v, ((0, 0), (0, tp), (0, 0), (0, dp)))
    # padded positions are masked by `pos`; padded head dims contribute 0
    # to scores but change the scale -> rescale q to compensate
    if dp:
        q = q * jnp.sqrt((d + dp) / d).astype(q.dtype)
    out = decode_attention(q, k, v, pos, bk=bk, interpret=interpret)
    return out[:, :, :d]
