"""Flash-decoding: single-token attention against a long KV cache.

One query token per sequence attends to T cached positions.  The kernel
splits the cache into kv blocks along the sequential minor grid dim and
combines partial softmax statistics in VMEM scratch — the TPU analogue of
GPU flash-decoding's split-KV reduction, with the MXU doing [H_blk, bk]
score tiles.  Invalid (future / unwritten) slots are masked from ``pos``.

This kernel is also the per-shard body of the shard_map sequence-sharded
decode path (§Perf): each model-axis shard runs it over its cache slice,
then partial (m, l, acc) combine with a tiny psum.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, m_ref, l_ref, acc_ref,
                *, scale: float, bk: int, kv_heads: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [H, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    pos = pos_ref[0]  # scalar: number of valid cache slots

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(
    q: jax.Array,  # [B, H, d]   one token per sequence
    k: jax.Array,  # [B, T, KV, d]
    v: jax.Array,
    pos: jax.Array,  # [B] int32: valid cache length per sequence
    *,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, d = q.shape
    T, KV = k.shape[1], k.shape[2]
    if H != KV:
        # GQA: fold the group into the head dim by repeating kv reads —
        # the BlockSpec maps q-head blocks onto their kv head.
        assert H % KV == 0
    scale = 1.0 / math.sqrt(d)
    nk = pl.cdiv(T, bk)

    # one kv-head group at a time: grid (B*KV, nk); q rows grouped per kv
    G = H // KV
    qg = q.reshape(B * KV, G, d)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, T, 1, d)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, T, 1, d)
    posg = jnp.repeat(pos, KV)

    out = pl.pallas_call(
        functools.partial(_dec_kernel, scale=scale, bk=bk, kv_heads=KV),
        grid=(B * KV, nk),
        in_specs=[
            pl.BlockSpec((1, G, d), lambda g, ki: (g, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda g, ki: (g, ki, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda g, ki: (g, ki, 0, 0)),
            pl.BlockSpec((1,), lambda g, ki: (g,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, G, d), lambda g, ki: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg, posg)
    return out.reshape(B, KV, G, d).reshape(B, H, d)
