"""Oracle for single-token decode attention (pure jnp)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos: jax.Array) -> jax.Array:
    """q [B,H,d]; k/v [B,T,KV,d]; pos [B] valid lengths -> [B,H,d]."""
    B, H, d = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.reshape(B, KV, G, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32))
    s = s / math.sqrt(d)
    valid = jnp.arange(T)[None, :] < pos[:, None]  # [B, T]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, d).astype(q.dtype)
