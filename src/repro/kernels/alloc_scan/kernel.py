"""Burst allocation scan (decide → debit → place) — Pallas TPU.

The sequential core of ``repro.core.allocator``: B task requests walk the
carry (residual tiles, scalar totals, stamped mask, head-of-line flag) in
admission order.  TPU-native blocking follows ``mamba_scan``: the grid's
single (minor, sequential) dimension walks row chunks; the carry lives in
VMEM/SMEM scratch for the whole burst (never returns to HBM), and each
chunk streams only its row scalars and its ``[chunk, B]`` slab of the
mid-burst correction tables.  Within a chunk the recurrence is a short
``fori_loop``; every step is branchless — the Alg. 3 evaluator lattice,
the placement key and both argmaxes (flat max + min-index, exact
first-index tie semantics) are VPU element-wise ops over the resident
``[num_blocks, LANE]`` residual tiles.

Decisions are bit-for-bit identical to ``ref.alloc_scan_ref``: max /
compare / select are exact, and all rounding arithmetic (demand
correction, evaluator, debits) uses the same float32 expressions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.evaluation import FCFS_SCENARIO, EvalInputs, evaluate
from repro.core.placement import placement_key

from repro.kernels.alloc_scan.ref import LANE

_BIG_I32 = 2**31 - 1  # python int: traced literals may not be captured


def _flat_argmax(x: jax.Array, flat_idx: jax.Array):
    """(max value, first flat index attaining it) — both exact."""
    m = jnp.max(x)
    idx = jnp.min(jnp.where(x == m, flat_idx,
                            jnp.full_like(flat_idx, _BIG_I32)))
    return m, idx


def _pick(x: jax.Array, flat_idx: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather x[idx] from tiles via a one-hot masked sum (exact)."""
    return jnp.sum(jnp.where(flat_idx == idx, x, jnp.zeros_like(x)))


def _scan_kernel(
    # inputs
    rc2_ref, rm2_ref, cc2_ref, cm2_ref, tot_c_ref, tot_m_ref,
    cpu_ref, mem_ref, min_cpu_ref, min_mem_ref, base_c_ref, base_m_ref,
    dc_ref, dm_ref, self_ref, attempt_ref, pending_ref,
    # outputs
    alloc_c_ref, alloc_m_ref, node_ref, accept_ref, attempted_ref,
    scenario_ref,
    # scratch
    rc_s, rm_s, stamped_s, tot_s, blocked_s,
    *,
    chunk: int,
    alpha: float,
    beta: float,
    policy: str,
    mode: str,
):
    si = pl.program_id(0)
    nb, lane = rc_s.shape
    num_rows = stamped_s.shape[1]
    # Cluster shards (repro.cluster.federation): K per-shard totals in
    # SMEM, blocks cluster-major with a uniform nb // K blocks per shard.
    # The legacy single-cluster burst is simply K=1.
    num_shards = tot_s.shape[1]
    shard_span = (nb // num_shards) * lane
    blk_ids = jax.lax.broadcasted_iota(jnp.int32, (nb, lane), 0)
    off_ids = jax.lax.broadcasted_iota(jnp.int32, (nb, lane), 1)
    flat_idx = blk_ids * lane + off_ids
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (1, num_rows), 1)[0]

    @pl.when(si == 0)
    def _init():
        rc_s[...] = rc2_ref[...]
        rm_s[...] = rm2_ref[...]
        stamped_s[...] = jnp.zeros_like(stamped_s)
        for k in range(num_shards):  # static unroll: K is tiny
            tot_s[0, k] = tot_c_ref[0, k]
            tot_s[1, k] = tot_m_ref[0, k]
        blocked_s[0] = jnp.int32(0)

    def step(t, _):
        rid = si * chunk + t
        rc2, rm2 = rc_s[...], rm_s[...]
        stamped = stamped_s[0]
        cpu, mem = cpu_ref[t], mem_ref[t]
        self_slot = self_ref[t]
        pending = pending_ref[t] != 0
        blocked = blocked_s[0] != 0
        attempt = (attempt_ref[t] != 0) & ~(pending & blocked)
        if mode == "aras":
            req_c = base_c_ref[t] + jnp.sum(dc_ref[t] * stamped)
            req_m = base_m_ref[t] + jnp.sum(dm_ref[t] * stamped)
            re_max_cpu, imax = _flat_argmax(rc2, flat_idx)
            re_max_mem = _pick(rm2, flat_idx, imax)
            # Federation-wide totals: same static left-fold as the ref's
            # _fold_sum, so both backends re-associate identically.
            glob_c, glob_m = tot_s[0, 0], tot_s[1, 0]
            for k in range(1, num_shards):
                glob_c = glob_c + tot_s[0, k]
                glob_m = glob_m + tot_s[1, k]
            result = evaluate(
                EvalInputs(
                    task_cpu=cpu,
                    task_mem=mem,
                    request_cpu=req_c,
                    request_mem=req_m,
                    total_residual_cpu=glob_c,
                    total_residual_mem=glob_m,
                    re_max_cpu=re_max_cpu,
                    re_max_mem=re_max_mem,
                ),
                alpha,
            )
            alloc_c, alloc_m = result.cpu, result.mem
            scenario = result.scenario
            ok = (alloc_c >= min_cpu_ref[t]) & (alloc_m >= min_mem_ref[t] + beta)
        else:  # fcfs
            alloc_c, alloc_m = cpu, mem
            scenario = jnp.int32(FCFS_SCENARIO)
            ok = jnp.bool_(True)

        key = placement_key(policy, rc2, rm2, alloc_c, alloc_m,
                            cc2_ref[...], cm2_ref[...])
        kmax, node = _flat_argmax(key, flat_idx)
        fits_any = kmax > -jnp.inf

        accept = attempt & ok & fits_any
        debit = accept.astype(rc2.dtype)
        hit = flat_idx == node
        rc_s[...] = rc2 - jnp.where(hit, alloc_c * debit, 0.0)
        rm_s[...] = rm2 - jnp.where(hit, alloc_m * debit, 0.0)
        # Debit the owning shard only (static unroll, branchless: the
        # indicator is 1.0 on the owner, 0.0 elsewhere — exact either way).
        owner = node // shard_span
        for k in range(num_shards):
            ind = (owner == k).astype(rc2.dtype)
            tot_s[0, k] = tot_s[0, k] - alloc_c * debit * ind
            tot_s[1, k] = tot_s[1, k] - alloc_m * debit * ind
        stamped_s[0] = jnp.where((row_ids == rid) & (self_slot >= 0),
                                 debit, stamped)
        blocked_s[0] = (blocked | (pending & attempt & ~(ok & fits_any))
                        ).astype(jnp.int32)

        alloc_c_ref[t] = alloc_c
        alloc_m_ref[t] = alloc_m
        node_ref[t] = jnp.where(fits_any, node, jnp.int32(-1))
        accept_ref[t] = accept.astype(jnp.int32)
        attempted_ref[t] = attempt.astype(jnp.int32)
        scenario_ref[t] = scenario
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "alpha", "beta", "policy", "mode", "interpret"),
)
def alloc_scan_pallas(
    rc2: jax.Array,  # [nb, LANE] f32 residual tiles (RES_PAD padded)
    rm2: jax.Array,
    cap_cpu2: jax.Array,
    cap_mem2: jax.Array,
    tot_cpu: jax.Array,  # scalar f32, or [K] per-shard federated totals
    tot_mem: jax.Array,
    b_cpu: jax.Array,  # [B] f32
    b_mem: jax.Array,
    b_min_cpu: jax.Array,
    b_min_mem: jax.Array,
    base_cpu: jax.Array,  # [B] f32 hoisted window demand
    base_mem: jax.Array,
    delta_cpu: jax.Array,  # [B, B] f32
    delta_mem: jax.Array,
    b_self: jax.Array,  # [B] int32
    b_attempt: jax.Array,  # [B] int32 (bools as ints for ref-friendliness)
    b_pending: jax.Array,  # [B] int32
    *,
    chunk: int = 128,
    alpha: float,
    beta: float,
    policy: str,
    mode: str,
    interpret: bool = False,
):
    """Returns (alloc_cpu, alloc_mem, node, accept, attempted, scenario)."""
    num_rows = b_cpu.shape[0]
    nb, lane = rc2.shape
    assert lane == LANE, (lane, LANE)
    chunk = min(chunk, num_rows)
    assert num_rows % chunk == 0, (num_rows, chunk)
    grid = (num_rows // chunk,)
    # Scalar legacy totals become a K=1 federation; [K] vectors carry one
    # total per cluster shard (blocks cluster-major, nb % K == 0).
    tot_c2 = jnp.atleast_1d(tot_cpu).reshape(1, -1)
    tot_m2 = jnp.atleast_1d(tot_mem).reshape(1, -1)
    num_shards = tot_c2.shape[1]
    assert nb % num_shards == 0, (nb, num_shards)

    whole = pl.BlockSpec((nb, lane), lambda si: (0, 0))
    scalar = pl.BlockSpec((1, num_shards), lambda si: (0, 0),
                          memory_space=pltpu.SMEM)
    row_f32 = pl.BlockSpec((chunk,), lambda si: (si,))
    # Correction-table slab: [chunk, B] for ARAS, width-1 placeholder
    # (never read) in FCFS mode.
    slab = pl.BlockSpec((chunk, delta_cpu.shape[1]), lambda si: (si, 0))

    outs = pl.pallas_call(
        functools.partial(
            _scan_kernel, chunk=chunk, alpha=alpha, beta=beta,
            policy=policy, mode=mode,
        ),
        grid=grid,
        in_specs=[
            whole, whole, whole, whole, scalar, scalar,
            row_f32, row_f32, row_f32, row_f32, row_f32, row_f32,
            slab, slab, row_f32, row_f32, row_f32,
        ],
        out_specs=[row_f32, row_f32, row_f32, row_f32, row_f32, row_f32],
        out_shape=[
            jax.ShapeDtypeStruct((num_rows,), jnp.float32),
            jax.ShapeDtypeStruct((num_rows,), jnp.float32),
            jax.ShapeDtypeStruct((num_rows,), jnp.int32),
            jax.ShapeDtypeStruct((num_rows,), jnp.int32),
            jax.ShapeDtypeStruct((num_rows,), jnp.int32),
            jax.ShapeDtypeStruct((num_rows,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nb, lane), jnp.float32),
            pltpu.VMEM((nb, lane), jnp.float32),
            pltpu.VMEM((1, num_rows), jnp.float32),
            pltpu.SMEM((2, num_shards), jnp.float32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
    )(
        rc2, rm2, cap_cpu2, cap_mem2,
        tot_c2, tot_m2,
        b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
        delta_cpu, delta_mem,
        b_self, b_attempt, b_pending,
    )
    alloc_c, alloc_m, node, accept, attempted, scenario = outs
    return (alloc_c, alloc_m, node, accept.astype(bool),
            attempted.astype(bool), scenario)
