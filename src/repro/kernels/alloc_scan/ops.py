"""Backend dispatch for the sequential burst-allocation core.

Concrete backends live in the ``repro.api.registry.BACKENDS`` registry
(uniform signature: the :func:`alloc_scan` argument list minus
``backend``); ``auto`` resolves to the Pallas kernel on TPU and the
``lax.scan`` reference elsewhere.  A third-party sequential core (e.g. a
GPU lowering) registers itself and becomes selectable via
``AllocatorConfig.backend`` without edits here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api.registry import BACKENDS
from repro.kernels.alloc_scan.kernel import alloc_scan_pallas
from repro.kernels.alloc_scan.ref import alloc_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@BACKENDS.register(
    "scan",
    capabilities=("portable",),
    doc="lax.scan reference core — runs on any JAX backend")
def _scan_backend(
    rc2, rm2, cap_cpu2, cap_mem2, tot_cpu, tot_mem,
    b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
    delta_cpu, delta_mem, b_self, b_attempt, b_pending,
    *, alpha, beta, policy, mode,
):
    return alloc_scan_ref(
        rc2, rm2, cap_cpu2, cap_mem2, tot_cpu, tot_mem,
        b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
        delta_cpu, delta_mem, b_self, b_attempt, b_pending,
        alpha=alpha, beta=beta, policy=policy, mode=mode,
    )


@BACKENDS.register(
    "pallas",
    capabilities=("tpu_native", "vmem_resident"),
    doc="Pallas TPU kernel, VMEM-resident carry (interpret mode off-TPU)")
def _pallas_backend(
    rc2, rm2, cap_cpu2, cap_mem2, tot_cpu, tot_mem,
    b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
    delta_cpu, delta_mem, b_self, b_attempt, b_pending,
    *, alpha, beta, policy, mode,
):
    return alloc_scan_pallas(
        rc2, rm2, cap_cpu2, cap_mem2,
        jnp.asarray(tot_cpu, jnp.float32), jnp.asarray(tot_mem, jnp.float32),
        b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
        delta_cpu, delta_mem,
        b_self.astype(jnp.int32),
        b_attempt.astype(jnp.int32),
        b_pending.astype(jnp.int32),
        alpha=alpha, beta=beta, policy=policy, mode=mode,
        interpret=not _on_tpu(),
    )


ALLOC_BACKENDS = ("auto",) + BACKENDS.names()


def resolve_backend(backend: str) -> str:
    """``auto`` → the Pallas kernel on TPU, the ``lax.scan`` ref elsewhere."""
    if backend == "auto":
        return "pallas" if _on_tpu() else "scan"
    BACKENDS.get(backend)  # actionable "unknown alloc backend" on a typo
    return backend


def alloc_scan(
    rc2, rm2, cap_cpu2, cap_mem2, tot_cpu, tot_mem,
    b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
    delta_cpu, delta_mem, b_self, b_attempt, b_pending,
    *,
    alpha: float,
    beta: float,
    policy: str,
    mode: str,
    backend: str,
):
    """Run the sequential core on a concrete backend (``scan``|``pallas``).

    Callers resolve ``auto`` once via :func:`resolve_backend` before
    dispatch.  ``tot_cpu``/``tot_mem`` are either scalars (legacy
    single-cluster) or ``[K]`` per-shard federated totals
    (``repro.cluster.federation``; residual tiles cluster-major with
    ``nb % K == 0``).  All registered backends return bit-identical
    ``(alloc_cpu, alloc_mem, node, accept, attempted, scenario)`` row
    arrays — gated by ``tests/test_alloc_scan.py`` and the cross-shard
    parity suite.
    """
    if backend == "auto":
        raise ValueError(
            "alloc_scan needs a concrete backend, got 'auto' "
            "(resolve it via resolve_backend first)"
        )
    return BACKENDS.get(backend).factory(
        rc2, rm2, cap_cpu2, cap_mem2, tot_cpu, tot_mem,
        b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
        delta_cpu, delta_mem, b_self, b_attempt, b_pending,
        alpha=alpha, beta=beta, policy=policy, mode=mode,
    )
