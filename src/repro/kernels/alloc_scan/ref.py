"""Sequential allocation core — ``lax.scan`` reference implementation.

One step = one task request of an arrival burst, decided against the
*carry*: residual tiles, O(1) cluster totals, the stamped-row mask (whose
records started mid-burst) and the head-of-line flag.  Everything O(T)
(knowledge-base window demand) and O(m)-reduction-per-step (cluster
totals) is hoisted out by the caller (``repro.core.allocator``):

* ``base_cpu/base_mem [B]`` — per-row in-window demand over the record
  table at its pre-burst ``t_start`` (one ``[B, T]`` masked reduction);
* ``delta_cpu/delta_mem [B, B]`` — the correction table:
  ``delta[i, j]`` is what row *j*'s record adds to row *i*'s window
  demand **iff** row *j* was accepted (stamped to ``t_start = now``)
  earlier in the burst, minus its pre-burst contribution already in
  ``base[i]``.  The scan consumes it with a triangular mask carried as
  ``stamped``: at step *i* only rows *j < i* can be stamped.
* ``tot_cpu/tot_mem`` — cluster residual totals, summed once and then
  debited O(1) per accepted row (Alg. 1 lines 15-18 maintained
  incrementally instead of re-reduced over ``[m]`` every step).

Residuals are shaped ``[num_blocks, LANE]`` (padding lanes carry
``RES_PAD`` so they never fit and never win an argmax).  Per-step
reductions are two-stage — a block-max along the lane axis, then tiny
argmaxes over block maxima — which keeps exact first-index tie semantics
(max/compare are exact in IEEE) while avoiding the fork-join cost of a
flat ``[m]`` argmax on CPU.  The Pallas kernel computes the same values
with flat max + min-index reductions; results are bit-identical.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.evaluation import FCFS_SCENARIO, EvalInputs, evaluate
from repro.core.placement import placement_key

# Lane width of the residual tiles ([num_blocks, LANE]); matches the TPU
# lane dimension so the Pallas kernel shares the layout.  Canonically
# defined by the federation layout module (which owns the tile layout and
# must stay import-cycle-free); re-exported here for the kernel callers.
from repro.cluster.federation import LANE, pad_tiles  # noqa: E402

# Padding residual: loses every argmax and never fits any request.
RES_PAD = -1e30


def _fold_sum(vec: jax.Array) -> jax.Array:
    """Static left-fold sum of a tiny [K] vector — exact order.

    The federated core and the Pallas kernel must agree bit-for-bit on
    the federation-wide total, so both reduce the per-shard totals in
    the same (unrolled, left-to-right) order.  At K=1 this is the
    identity — the legacy scalar total, untouched.
    """
    acc = vec[0]
    for k in range(1, vec.shape[0]):
        acc = acc + vec[k]
    return acc


def _tile_argmax(tiles: jax.Array, bmax: jax.Array, num_shards: int = 1
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Two-stage exact argmax over [nb, LANE] given its block maxima.

    Returns ``(block, offset, tiles[block])``.  First-max-index tie
    semantics in both stages — identical to a flat ``argmax`` and to the
    Pallas kernel's flat min-index reduction, since max/compare are
    exact.

    ``num_shards > 1`` runs the block stage per cluster shard and picks
    the winner with a cheap [K] cross-shard argmax reduce.  The block
    axis is cluster-major, so "first shard attaining the max, first
    block within it" is exactly the flat first-max block — federation
    changes where the reduction runs, not its result.
    """
    if num_shards == 1:
        blk = jnp.argmax(bmax)
    else:
        nb_per = bmax.shape[0] // num_shards
        smax = bmax.reshape(num_shards, nb_per)
        shard = jnp.argmax(jnp.max(smax, axis=1))  # cross-shard reduce
        within = jnp.argmax(
            jax.lax.dynamic_index_in_dim(smax, shard, 0, keepdims=False))
        blk = shard * nb_per + within
    row = jax.lax.dynamic_index_in_dim(tiles, blk, 0, keepdims=False)
    return blk, jnp.argmax(row), row


def alloc_step(carry, row, cap_cpu2, cap_mem2, *, alpha, beta, policy, mode):
    """Decide one request and debit the carry — the shared step semantics.

    Also used standalone (jitted at batch 1) by the engine's per-task
    replay mode, which reconstructs the carry from its own incremental
    caches between dispatches; the scan, the Pallas kernel and the replay
    therefore execute the same float32 arithmetic and agree bit-for-bit.

    Federated mode is selected by the carry's totals shape: scalar totals
    are the legacy single-cluster path, a ``[K]`` vector means K cluster
    shards laid out cluster-major along the block axis (uniform
    ``nb_per = nb // K`` blocks per shard — ``repro.cluster.federation``).
    The evaluator then sees the federation-wide total (exact static fold),
    argmaxes reduce per-shard then cross-shard, and an accept debits only
    the owning shard's total.
    """
    rc2, rm2, bmax, tot_c, tot_m, stamped, blocked = carry
    (cpu, mem, min_cpu, min_mem, base_c, base_m, d_c, d_m,
     self_slot, attempt_in, pending, rid) = row
    num_shards = tot_c.shape[0] if tot_c.ndim == 1 else 1
    federated = tot_c.ndim == 1
    # Head-of-line: once a pending row fails, later pending rows are
    # skipped (the seed's retry loop breaks at the first failure).
    attempt = attempt_in & ~(pending & blocked)
    if mode == "aras":
        # Alg. 1 lines 4-13: hoisted base + triangular mid-burst correction.
        req_c = base_c + jnp.sum(d_c * stamped)
        req_m = base_m + jnp.sum(d_m * stamped)
        # Alg. 1 lines 19-22: the max-residual-CPU node, via block maxima.
        blk, off, rc_blk = _tile_argmax(rc2, bmax, num_shards)
        re_max_cpu = rc_blk[off]
        re_max_mem = jax.lax.dynamic_index_in_dim(
            rm2, blk, 0, keepdims=False)[off]
        result = evaluate(
            EvalInputs(
                task_cpu=cpu,
                task_mem=mem,
                request_cpu=req_c,
                request_mem=req_m,
                total_residual_cpu=_fold_sum(tot_c) if federated else tot_c,
                total_residual_mem=_fold_sum(tot_m) if federated else tot_m,
                re_max_cpu=re_max_cpu,
                re_max_mem=re_max_mem,
            ),
            alpha,
        )
        alloc_c, alloc_m = result.cpu, result.mem
        scenario = result.scenario
        # Alg. 1 line 27 acceptance gate.
        ok = (alloc_c >= min_cpu) & (alloc_m >= min_mem + beta)
    else:  # fcfs: full declared request, placement-only feasibility
        alloc_c, alloc_m = cpu, mem
        scenario = jnp.int32(FCFS_SCENARIO)
        ok = jnp.bool_(True)

    key = placement_key(policy, rc2, rm2, alloc_c, alloc_m,
                        cap_cpu2, cap_mem2)
    pblk, poff, key_row = _tile_argmax(key, jnp.max(key, axis=1), num_shards)
    fits_any = key_row[poff] > -jnp.inf
    node = (pblk * LANE + poff).astype(jnp.int32)

    accept = attempt & ok & fits_any
    debit = accept.astype(rc2.dtype)
    rc2 = rc2.at[pblk, poff].add(-alloc_c * debit)
    rm2 = rm2.at[pblk, poff].add(-alloc_m * debit)
    if federated:
        # Only the shard owning the chosen block pays for the accept;
        # ``debit · onehot`` keeps the arithmetic identical to the scalar
        # path on the owner (·1.0) and a no-op elsewhere (·0.0).
        owner = pblk // (rc2.shape[0] // num_shards)
        onehot = (jnp.arange(num_shards) == owner).astype(rc2.dtype)
        tot_c = tot_c - alloc_c * debit * onehot
        tot_m = tot_m - alloc_m * debit * onehot
    else:
        tot_c = tot_c - alloc_c * debit
        tot_m = tot_m - alloc_m * debit
    if mode == "aras":
        # Only the debited block's maximum can have changed.
        bmax = bmax.at[pblk].set(jnp.max(
            jax.lax.dynamic_index_in_dim(rc2, pblk, 0, keepdims=False)))
    # mark_started: the accepted record now competes at t_start = now,
    # visible to every later row through its delta column.
    stamped = jnp.where(
        (jnp.arange(stamped.shape[0]) == rid) & (self_slot >= 0),
        debit, stamped,
    )
    blocked = blocked | (pending & attempt & ~(ok & fits_any))
    out = (
        alloc_c,
        alloc_m,
        jnp.where(fits_any, node, jnp.int32(-1)),
        accept,
        attempt,
        scenario,
    )
    return (rc2, rm2, bmax, tot_c, tot_m, stamped, blocked), out


def alloc_scan_ref(
    rc2: jax.Array,  # [nb, LANE] f32 residual CPU tiles (RES_PAD padded)
    rm2: jax.Array,  # [nb, LANE] f32
    cap_cpu2: jax.Array,  # [nb, LANE] f32 allocatable capacity tiles
    cap_mem2: jax.Array,  # [nb, LANE] f32
    tot_cpu: jax.Array,  # scalar f32 Σ residual cpu (real nodes only),
    #                      or [K] per-shard totals in federated mode
    tot_mem: jax.Array,  # scalar f32 (or [K])
    b_cpu: jax.Array,  # [B] f32 batch rows, admission order
    b_mem: jax.Array,  # [B] f32
    b_min_cpu: jax.Array,  # [B] f32
    b_min_mem: jax.Array,  # [B] f32
    base_cpu: jax.Array,  # [B] f32 hoisted pre-burst window demand
    base_mem: jax.Array,  # [B] f32
    delta_cpu: jax.Array,  # [B, B] f32 mid-burst stamp corrections
    delta_mem: jax.Array,  # [B, B] f32
    b_self: jax.Array,  # [B] int32 record slot, -1 = none
    b_attempt: jax.Array,  # [B] bool (False = padding row)
    b_pending: jax.Array,  # [B] bool (retry-queue row: head-of-line rules)
    *,
    alpha: float,
    beta: float,
    policy: str,
    mode: str,
):
    """Run the sequential core over a whole burst with ``lax.scan``."""
    num_rows = b_cpu.shape[0]
    init = (
        rc2,
        rm2,
        jnp.max(rc2, axis=1),
        tot_cpu,
        tot_mem,
        jnp.zeros((num_rows,), rc2.dtype),
        jnp.bool_(False),
    )
    rows = (b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
            delta_cpu, delta_mem, b_self, b_attempt, b_pending,
            jnp.arange(num_rows, dtype=jnp.int32))

    def step(carry, row):
        return alloc_step(carry, row, cap_cpu2, cap_mem2,
                          alpha=alpha, beta=beta, policy=policy, mode=mode)

    _, outs = jax.lax.scan(step, init, rows)
    return outs
