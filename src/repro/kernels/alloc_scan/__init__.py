"""Sequential burst-allocation core (decide → debit → place).

``ref.py`` is the ``lax.scan`` reference; ``kernel.py`` the Pallas TPU
lowering (residuals resident in VMEM across the whole burst); ``ops.py``
the backend dispatcher used by ``repro.core.allocator``.
"""
from repro.kernels.alloc_scan.ops import alloc_scan, resolve_backend

__all__ = ["alloc_scan", "resolve_backend"]
