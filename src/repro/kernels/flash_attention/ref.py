"""Pure-jnp oracle for flash attention (no Pallas, no blocking)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q [B,S,H,d]; k/v [B,T,KV,d] (GQA) -> [B,S,H,d], fp32 accumulation."""
    B, S, H, d = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.reshape(B, S, KV, G, d).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32))
    s = s / math.sqrt(d)
    if causal:
        q_pos = jnp.arange(S)[:, None]
        k_pos = jnp.arange(T)[None, :]
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, d).astype(q.dtype)
