"""Blocked causal/windowed flash-attention forward (Pallas TPU).

TPU-native adaptation: q/k/v tiles live in VMEM with MXU-aligned block
shapes (bq × d and bk × d, multiples of 128 on the lane dim); the online-
softmax running max/denominator/accumulator sit in VMEM scratch that
persists across the sequential kv-block grid dimension (TPU grids execute
minor-dim-sequentially, so scratch carries state — the Pallas analogue of
a CUDA persistent-CTA loop).

Grid: (batch, q_heads, q_blocks, kv_blocks); GQA maps q-head h to kv head
h // (H // KV) in the k/v index maps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window, bq: int, bk: int,
               kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # [bq, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < kv_len  # padding
    if causal:
        mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # [bq, bk]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret", "kv_len",
                     "head_dim"))
def flash_attention_fwd(
    q: jax.Array,  # [B, S, H, d]   (d padded to 128-multiple by ops.py)
    k: jax.Array,  # [B, T, KV, d]
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
    kv_len: int = 0,  # true (unpadded) kv length; 0 -> T
    head_dim: int = 0,  # true head dim for the softmax scale; 0 -> d
) -> jax.Array:
    B, S, H, d = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(head_dim or d)
    nq = pl.cdiv(S, bq)
    nk = pl.cdiv(T, bk)

    grid = (B, H, nq, nk)
    return pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk,
                          kv_len=kv_len or T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # denominator
            pltpu.VMEM((bq, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
