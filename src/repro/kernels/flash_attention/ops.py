"""Jit'd public wrapper: padding, GQA plumbing, backend dispatch.

``flash_attention`` pads the head dim to a 128 lane multiple and the kv
length to the block size (masked inside the kernel), runs the Pallas
kernel (interpret=True off-TPU), and slices back.  The custom_vjp uses
the reference path for the backward (recompute — memory-light), so the
kernel is usable inside ``train_step``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    interpret: Optional[bool] = None):
    return _fwd_impl(q, k, v, causal, window, interpret)


def _fwd_impl(q, k, v, causal, window, interpret):
    if interpret is None:
        interpret = not _on_tpu()
    B, S, H, d = q.shape
    T = k.shape[1]
    bq = min(128, max(8, 1 << (S - 1).bit_length()))
    bk = min(128, max(8, 1 << (T - 1).bit_length()))
    qp = _pad_to(_pad_to(q, 3, 128), 1, bq)
    kp = _pad_to(_pad_to(k, 3, 128), 1, bk)
    vp = _pad_to(_pad_to(v, 3, 128), 1, bk)
    out = flash_attention_fwd(
        qp, kp, vp, causal=causal, window=window,
        bq=min(bq, qp.shape[1]), bk=min(bk, kp.shape[1]),
        interpret=interpret, kv_len=T, head_dim=d)
    return out[:, :S, :, :d]


def _vjp_fwd(q, k, v, causal, window, interpret):
    return _fwd_impl(q, k, v, causal, window, interpret), (q, k, v)


def _vjp_bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
