"""Chunked selective-scan (Mamba-1 recurrence) — Pallas TPU.

    h_t = da_t ⊙ h_{t-1} + dbx_t          (per channel × state)

TPU-native blocking: the (B, S, di, n) recurrence tiles the *channel* dim
into VMEM-sized blocks and walks sequence chunks along the last (minor,
sequential) grid dimension; the inter-chunk carry lives in VMEM scratch
(never returns to HBM).  Within a chunk the recurrence is a short
``fori_loop`` of [bd, n] VPU element-wise ops — d_state (16) rides the
lane dim, channels the sublane dim.  This avoids materializing the
(B, S, di, n) tensor in HBM more than once (read da/dbx, write h).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(da_ref, dbx_ref, h0_ref, h_ref, hf_ref, carry_ref, *,
                 chunk: int):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        da_t = da_ref[0, t].astype(jnp.float32)  # [bd, n]
        dbx_t = dbx_ref[0, t].astype(jnp.float32)
        h = da_t * h + dbx_t
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, carry_ref[...])
    carry_ref[...] = h

    @pl.when(si == ns - 1)
    def _final():
        hf_ref[0] = h.astype(hf_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(
    da: jax.Array,  # [B, S, di, n] fp32
    dbx: jax.Array,  # [B, S, di, n] fp32
    h0: jax.Array,  # [B, di, n] fp32
    *,
    chunk: int = 128,
    block_d: int = 256,
    interpret: bool = False,
):
    """Returns (h [B,S,di,n], h_final [B,di,n])."""
    B, S, di, n = da.shape
    chunk = min(chunk, S)
    block_d = min(block_d, di)
    assert S % chunk == 0 and di % block_d == 0, (S, chunk, di, block_d)
    grid = (B, di // block_d, S // chunk)

    h, hf = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d, n),
                         lambda b, dI, si: (b, si, dI, 0)),
            pl.BlockSpec((1, chunk, block_d, n),
                         lambda b, dI, si: (b, si, dI, 0)),
            pl.BlockSpec((1, block_d, n), lambda b, dI, si: (b, dI, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d, n),
                         lambda b, dI, si: (b, si, dI, 0)),
            pl.BlockSpec((1, block_d, n), lambda b, dI, si: (b, dI, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di, n), jnp.float32),
            jax.ShapeDtypeStruct((B, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(da, dbx, h0)
    return h, hf
