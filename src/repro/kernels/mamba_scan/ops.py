"""Public wrapper for the chunked selective scan."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.kernel import mamba_scan
from repro.kernels.mamba_scan.ref import scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def chunked_scan(da: jax.Array, dbx: jax.Array, h0: jax.Array,
                 interpret=None) -> Tuple[jax.Array, jax.Array]:
    """Dispatch to the Pallas kernel with shape-legal chunking."""
    if interpret is None:
        interpret = not _on_tpu()
    B, S, di, n = da.shape
    chunk = _largest_divisor(S, 128)
    block_d = _largest_divisor(di, 256)
    return mamba_scan(da, dbx, h0, chunk=chunk, block_d=block_d,
                      interpret=interpret)


def _largest_divisor(x: int, cap: int) -> int:
    for c in range(min(cap, x), 0, -1):
        if x % c == 0:
            return c
    return 1
