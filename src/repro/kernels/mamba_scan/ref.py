"""Sequential-scan oracle for the Mamba recurrence (pure jnp)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_ref(da: jax.Array, dbx: jax.Array, h0: jax.Array):
    """h_t = da_t * h_{t-1} + dbx_t over axis 1.

    da/dbx: [B, S, di, n]; h0: [B, di, n].
    Returns (h [B,S,di,n], h_final [B,di,n]).
    """
    def step(h, x):
        a, b = x
        h = a * h + b
        return h, h

    hf, h = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(da, 1, 0).astype(jnp.float32),
         jnp.moveaxis(dbx, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(h, 0, 1), hf
