"""Online arrival-rate forecasting for predictive allocation.

The engine's windowed drain and the ARAS demand window are both
*reactive*: they only see arrivals that already happened.  The adaptive
scalers this reproduction positions itself against (AHPA,
arXiv:2303.03640) get their headline wins from the opposite move —
fitting a small model to the request stream online and provisioning for
the load it predicts.  This module is that move, built entirely from
in-repo parts:

* **Features** — the last ``ForecastConfig.window`` inter-arrival gaps
  of the injection stream, log-compressed and normalized by the running
  mean gap (``log1p(gap / mean)``), so the same network generalizes
  across absolute time scales and burst/quiet regimes land on
  well-separated inputs.
* **Model** — the gated-SiLU MLP of :mod:`repro.models.layers`
  (``init_mlp``/``mlp``) with a linear readout, predicting the next
  normalized log-gap.  A few hundred parameters: one device dispatch to
  train, one to predict.
* **Training** — online AdamW (:mod:`repro.optim`) on the ring buffer
  of recent gaps, one squared-error step per ``train_every``
  observations.  Everything is seed-deterministic given the arrival
  sequence: parameter init from ``ForecastConfig.seed``, no data
  shuffling, fixed-shape buffers (masked) so jit compiles once.

Two consumers read the forecaster (see ``repro.engine.kubeadaptor``):

* :meth:`ArrivalForecaster.fold_window` sizes the engine's fold
  deadline from the predicted next gap — wide windows while a burst is
  predicted (arrivals fold into few fused dispatches), collapsing
  toward zero in quiet stretches (no pointless decision latency);
* :meth:`ArrivalForecaster.horizon_demand` converts the predicted rate
  into the expected resource demand of the next ``horizon`` seconds —
  the ghost record the ``adaptive_scaling`` allocator prices against,
  so quotas tighten *before* the burst lands.

Until ``min_history`` gaps are observed the forecaster abstains
(:meth:`predicted_gap` returns ``None``) and both consumers fall back
to the static configuration — cold starts degrade to today's engine.
"""
from __future__ import annotations

import collections
import functools
from typing import Deque, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import ForecastConfig
from repro.models.layers import init_mlp, mlp
from repro.optim import AdamW

# A wildly over-shooting early prediction must not freeze the engine:
# predicted gaps are clipped to this many mean gaps, and the expected
# arrival count of a demand horizon to this many workflows.
_MAX_GAP_SCALE = 16.0
_MAX_HORIZON_ARRIVALS = 256.0


def init_forecaster(key: jax.Array, window: int, hidden: int):
    """Parameter pytree: the layer-library MLP plus a linear readout."""
    k_mlp, k_head = jax.random.split(key)
    return {
        "mlp": init_mlp(k_mlp, window, hidden),
        "head": {
            "w": (jax.random.normal(k_head, (window,), jnp.float32)
                  / np.sqrt(window)),
            "b": jnp.zeros((), jnp.float32),
        },
    }


def forecast_apply(params, feats: jax.Array) -> jax.Array:
    """``[..., W]`` normalized log-gap features -> predicted next one."""
    h = feats + mlp(params["mlp"], feats)  # residual keeps init ≈ mean gap
    return h @ params["head"]["w"] + params["head"]["b"]


@jax.jit
def _predict(params, feats):
    return forecast_apply(params, feats)


@functools.partial(jax.jit, static_argnames=("opt",))
def _train_step(params, opt_state, feats, targets, mask, *, opt: AdamW):
    """One masked squared-error AdamW step over the gap ring buffer."""

    def loss_fn(p):
        preds = forecast_apply(p, feats)
        se = jnp.square(preds - targets) * mask
        return jnp.sum(se) / jnp.maximum(jnp.sum(mask), 1.0)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state = opt.update(grads, opt_state, params)
    return params, opt_state, loss


class ArrivalForecaster:
    """Fit the injection stream online; predict the next gap + demand.

    ``observe`` is called once per workflow arrival (monotone
    timestamps).  The forecaster keeps a ring buffer of the last
    ``cfg.history`` inter-arrival gaps, a running mean gap (the feature
    normalizer) and the running mean per-arrival resource demand (the
    horizon-demand intensity); ``train_every`` observations trigger one
    AdamW step over every (window → next gap) pair in the ring.
    """

    def __init__(self, cfg: ForecastConfig):
        cfg.validate()
        self.cfg = cfg
        self._gaps: Deque[float] = collections.deque(maxlen=cfg.history)
        self._last_t: Optional[float] = None
        self._gap_sum = 0.0
        self._num_gaps = 0  # all gaps ever observed (not just the ring)
        self._cpu_sum = 0.0
        self._mem_sum = 0.0
        self._num_arrivals = 0
        self._opt = AdamW(learning_rate=cfg.lr, weight_decay=0.0,
                          clip_norm=1.0, warmup_steps=0, total_steps=0)
        self.params = init_forecaster(
            jax.random.key(cfg.seed), cfg.window, cfg.hidden)
        self.opt_state = self._opt.init(self.params)
        self.last_loss = float("nan")
        self.num_fits = 0
        self._cached_gap: Optional[float] = None
        self._cache_valid = False

    # ------------------------------------------------------------ ingest
    def observe(self, t: float, cpu: float = 0.0, mem: float = 0.0) -> None:
        """Record one arrival: its timestamp and total resource request."""
        self._num_arrivals += 1
        self._cpu_sum += float(cpu)
        self._mem_sum += float(mem)
        if self._last_t is not None:
            gap = max(float(t) - self._last_t, 0.0)
            self._gaps.append(gap)
            self._gap_sum += gap
            self._num_gaps += 1
        self._last_t = float(t)
        self._cache_valid = False
        if (self._num_gaps >= self.cfg.min_history
                and self._num_gaps % self.cfg.train_every == 0):
            self._fit()

    # ---------------------------------------------------------- features
    def _scale(self) -> float:
        """Running mean gap — the feature/prediction normalizer."""
        if self._num_gaps == 0 or self._gap_sum <= 0.0:
            return 1.0
        return self._gap_sum / self._num_gaps

    def _fit(self) -> None:
        """One masked AdamW step over the ring buffer's training pairs."""
        w = self.cfg.window
        gaps = np.asarray(self._gaps, np.float32)
        num_pairs = gaps.shape[0] - w
        if num_pairs < 1:
            return
        norm = np.log1p(gaps / np.float32(self._scale()))
        # Fixed [history - window] shapes so jit compiles exactly once.
        cap = self.cfg.history - w
        feats = np.zeros((cap, w), np.float32)
        targets = np.zeros((cap,), np.float32)
        mask = np.zeros((cap,), np.float32)
        idx = np.arange(num_pairs)[:, None] + np.arange(w)[None, :]
        feats[:num_pairs] = norm[idx]
        targets[:num_pairs] = norm[w:]
        mask[:num_pairs] = 1.0
        self.params, self.opt_state, loss = _train_step(
            self.params, self.opt_state, jnp.asarray(feats),
            jnp.asarray(targets), jnp.asarray(mask), opt=self._opt)
        self.last_loss = float(loss)
        self.num_fits += 1

    # --------------------------------------------------------- consumers
    @property
    def ready(self) -> bool:
        """Has the forecaster seen enough gaps to predict?"""
        return self._num_gaps >= self.cfg.min_history

    def predicted_gap(self) -> Optional[float]:
        """Predicted next inter-arrival gap in seconds; ``None`` while
        the history is too short to trust (cold start)."""
        if not self.ready:
            return None
        if not self._cache_valid:
            scale = self._scale()
            recent = np.asarray(self._gaps, np.float32)[-self.cfg.window:]
            feats = np.log1p(recent / np.float32(scale))
            y = float(_predict(self.params, jnp.asarray(feats)))
            gap = scale * float(np.expm1(y))
            self._cached_gap = float(
                np.clip(gap, 0.0, _MAX_GAP_SCALE * scale))
            self._cache_valid = True
        return self._cached_gap

    def fold_window(self, static_window: float) -> float:
        """Adaptive fold-window size in seconds.

        ``window_scale`` × the predicted gap, capped at ``max_window``;
        the static ``batch_window`` while the forecaster abstains.  A
        predicted burst (small gaps) folds tightly-spaced arrivals into
        one fused dispatch; a predicted quiet stretch collapses the
        window so lone arrivals decide immediately.
        """
        gap = self.predicted_gap()
        if gap is None:
            return static_window
        return float(min(self.cfg.window_scale * gap,
                         self.cfg.max_window))

    def horizon_demand(self) -> Tuple[float, float]:
        """Expected (cpu, mem) demand arriving within ``horizon`` seconds.

        Predicted arrival rate (1 / predicted gap) × horizon × the
        running mean per-arrival request — the ghost record the
        predictive allocator prices into its lifecycle window.  Zero
        while abstaining or with ``horizon=0`` (the consumer then adds
        nothing, falling back to plain ARAS).
        """
        gap = self.predicted_gap()
        if gap is None or self.cfg.horizon <= 0.0 \
                or self._num_arrivals == 0:
            return 0.0, 0.0
        expected = min(self.cfg.horizon / max(gap, 1e-3),
                       _MAX_HORIZON_ARRIVALS)
        return (expected * self._cpu_sum / self._num_arrivals,
                expected * self._mem_sum / self._num_arrivals)
