import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# backend initialization.  (Do not set this anywhere global — smoke tests
# and benches must keep seeing 1 device.)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real jitted computation (train_step for
train shapes; prefill / decode serve_step for inference shapes) against
the production mesh, with in/out shardings from the ShardingPolicy, then:

    lowered  = jax.jit(fn, in_shardings=..., out_shardings=...).lower(*specs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves the cell fits
    print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

and extracts collective bytes from the post-SPMD HLO for the roofline's
collective term.  Results land in ``results/dryrun/<cell>.json`` which
``benchmarks/roofline.py`` consumes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k [--multi-pod] [--all] [--out results/dryrun]
"""
import argparse
import dataclasses
import functools
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.api import SHAPES, ArchModel, ShapeSpec, build_model, input_specs
from repro.models.config import ModelConfig
from repro.optim import make_optimizer
from repro.parallel.act_sharding import activation_sharding
from repro.parallel.policy import ShardingPolicy
from repro.training.train_step import TrainState, init_train_state, make_train_step

# Archs whose long_500k cell is skipped: pure full-attention families
# (quadratic attention at 524288 is out of scope by assignment; see
# DESIGN §Arch-applicability).
LONG_OK = {"h2o-danube-1.8b", "falcon-mamba-7b", "jamba-1.5-large-398b"}


def cell_id(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def pick_optimizer(cfg: ModelConfig) -> Any:
    """Adafactor for 100B+ (state must fit the pod), AdamW otherwise."""
    big = cfg.param_count() > 50e9
    return make_optimizer("adafactor" if big else "adamw")


def _sharding_tree(policy: ShardingPolicy, spec_tree):
    return jax.tree.map(policy.named, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


# --------------------------------------------------------------- lowering

def lower_train(model: ArchModel, policy: ShardingPolicy, shape: ShapeSpec,
                grad_accum: int = 1):
    cfg = model.cfg
    optimizer = pick_optimizer(cfg)
    step_fn = make_train_step(model, optimizer, grad_accum=grad_accum)

    state_shapes = jax.eval_shape(
        lambda k: init_train_state(model, optimizer, k), jax.random.key(0))
    state_specs = policy.tree_specs(state_shapes)
    batch_shapes = input_specs(cfg, shape)
    batch_specs = policy.batch_spec(batch_shapes)

    jitted = jax.jit(
        step_fn,
        in_shardings=(_sharding_tree(policy, state_specs),
                      _sharding_tree(policy, batch_specs)),
        out_shardings=(_sharding_tree(policy, state_specs), None),
        donate_argnums=(0,),
    )
    return jitted.lower(state_shapes, batch_shapes)


def lower_prefill(model: ArchModel, policy: ShardingPolicy,
                  shape: ShapeSpec):
    cfg = model.cfg
    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    param_specs = policy.tree_specs(params_shapes)
    batch_shapes = input_specs(cfg, shape)
    batch_specs = policy.batch_spec(batch_shapes)
    cache_shapes = jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch,
                          shape.seq_len))
    cache_specs = policy.cache_spec(cache_shapes)

    def prefill_fn(params, batch):
        return model.prefill(params, batch, max_len=shape.seq_len)

    jitted = jax.jit(
        prefill_fn,
        in_shardings=(_sharding_tree(policy, param_specs),
                      _sharding_tree(policy, batch_specs)),
        out_shardings=(None, _sharding_tree(policy, cache_specs)),
    )
    return jitted.lower(params_shapes, batch_shapes)


def lower_decode(model: ArchModel, policy: ShardingPolicy,
                 shape: ShapeSpec):
    """serve_step: one new token against a cache of seq_len.

    Lowered inside serve-mode activation sharding: batch-replicated
    activations + 2D-sharded weights (see act_sharding docstring).
    """
    cfg = model.cfg
    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    param_specs = policy.tree_specs(params_shapes)
    cache_shapes = jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch,
                          shape.seq_len))
    cache_specs = policy.cache_spec(cache_shapes)
    tok_shapes = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    from jax.sharding import PartitionSpec as P

    tok_spec = P(None, None)  # serve mode: batch replicated

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    jitted = jax.jit(
        serve_step,
        in_shardings=(_sharding_tree(policy, param_specs),
                      policy.named(tok_spec),
                      _sharding_tree(policy, cache_specs)),
        out_shardings=(None, _sharding_tree(policy, cache_specs)),
        donate_argnums=(2,),
    )
    return jitted.lower(params_shapes, tok_shapes, cache_shapes)


# -------------------------------------------------------------- analysis

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result bytes of every collective op in the (post-SPMD) HLO."""
    out: Dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        result_ty, kind = m.group(1), m.group(2)
        nbytes = 0
        for dm in SHAPE_RE.finditer(result_ty):
            dt, dims = dm.group(1), dm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + float(nbytes)
    return out


def cost_dict(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` normalized to a flat dict.

    Depending on the jax version the method returns either a dict or a
    one-element list of dicts (one per executable); collapse both forms.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyse(lowered, compiled) -> Dict[str, Any]:
    cost = cost_dict(compiled)
    mem = compiled.memory_analysis()
    mem_info: Dict[str, Any] = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            mem_info[attr] = int(getattr(mem, attr))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "memory": mem_info,
        "collectives": coll,
        "collective_bytes_total": float(sum(coll.values())),
    }


# ----------------------------------------------------------- calibration
#
# XLA's HLO cost analysis visits while-loop (lax.scan) bodies ONCE, so a
# scanned L-layer stack under-reports flops/bytes by ~L×.  We calibrate by
# lowering the same cell with the stack UNROLLED at two small depths n1<n2
# (in units of the arch's repeating group) and extrapolating linearly:
#       m(n) = a + b·n   =>   m(L_true) = m(n1) + (m(n2)-m(n1))·(L−n1)/(n2−n1)
# The full scan-based compile remains the deployable artifact (its
# memory_analysis is what we report); calibration only fixes the counters.

def _calib_configs(cfg: ModelConfig):
    """Return (n1_cfg, n2_cfg, n1, n2, n_true) in group units."""
    r = dataclasses.replace
    if cfg.is_hybrid:
        g = cfg.hybrid_group
        return (r(cfg, num_layers=g, scan_layers=False),
                r(cfg, num_layers=2 * g, scan_layers=False),
                1, 2, cfg.num_layers // g)
    if cfg.is_vlm:
        e = cfg.cross_attn_every
        return (r(cfg, num_layers=e, scan_layers=False),
                r(cfg, num_layers=2 * e, scan_layers=False),
                1, 2, cfg.num_layers // e)
    if cfg.is_encdec:
        return (r(cfg, num_layers=1, encoder_layers=1, scan_layers=False),
                r(cfg, num_layers=2, encoder_layers=2, scan_layers=False),
                1, 2, cfg.num_layers)
    extra = 1 if cfg.first_layer_dense_ff > 0 else 0
    n_true = cfg.num_layers - extra
    return (r(cfg, num_layers=1 + extra, scan_layers=False),
            r(cfg, num_layers=2 + extra, scan_layers=False),
            1, 2, n_true)


def _cell_costs(cfg: ModelConfig, policy: ShardingPolicy, shape: ShapeSpec,
                grad_accum: int) -> Dict[str, float]:
    model = build_model(cfg)
    if shape.kind == "train":
        lowered = lower_train(model, policy, shape, grad_accum=grad_accum)
    elif shape.kind == "prefill":
        lowered = lower_prefill(model, policy, shape)
    else:
        lowered = lower_decode(model, policy, shape)
    compiled = lowered.compile()
    cost = cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "collective_bytes_total": float(sum(coll.values())),
        **{f"coll_{k}": v for k, v in coll.items()},
    }


def calibrate(cfg: ModelConfig, policy: ShardingPolicy, shape: ShapeSpec,
              grad_accum: int = 1) -> Dict[str, Any]:
    cfg1, cfg2, n1, n2, n_true = _calib_configs(cfg)
    m1 = _cell_costs(cfg1, policy, shape, grad_accum)
    m2 = _cell_costs(cfg2, policy, shape, grad_accum)
    out: Dict[str, Any] = {"n1": n1, "n2": n2, "n_true": n_true}
    for k in set(m1) | set(m2):
        a, b = m1.get(k, 0.0), m2.get(k, 0.0)
        out[k] = a + (b - a) * (n_true - n1) / (n2 - n1)
        out[f"{k}_n1"] = a
        out[f"{k}_n2"] = b
    return out


# ------------------------------------------------------------------ cells

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "results/dryrun",
             grad_accum: int = 1,
             calibrate_costs: bool = True,
             sp: bool = False,
             remat_policy: Optional[str] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    if grad_accum == 0:  # auto: microbatch the 50B+ models (fit HBM)
        grad_accum = 16 if cfg.param_count() > 50e9 else 1
    shape = SHAPES[shape_name]
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "cell": cell_id(arch, shape_name, multi_pod),
    }

    if shape_name == "long_500k" and arch not in LONG_OK:
        result["status"] = "skipped"
        result["reason"] = ("pure full-attention arch: long_500k requires "
                            "sub-quadratic attention (DESIGN "
                            "§Arch-applicability)")
        _save(result, out_dir)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = ShardingPolicy(mesh)
    model = build_model(cfg)

    t0 = time.time()
    try:
        with mesh, activation_sharding(policy, sp=sp,
                                       serve=(shape.kind == "decode")):
            if shape.kind == "train":
                lowered = lower_train(model, policy, shape,
                                      grad_accum=grad_accum)
            elif shape.kind == "prefill":
                lowered = lower_prefill(model, policy, shape)
            else:
                lowered = lower_decode(model, policy, shape)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        print(compiled.memory_analysis())
        print(compiled.cost_analysis())
        result.update(analyse(lowered, compiled))
        result["status"] = "ok"
        result["lower_s"] = round(t_lower, 2)
        result["compile_s"] = round(t_compile, 2)
        result["sharding_fallbacks"] = policy.fallbacks
        nd = len(mesh.devices.flatten())
        result["num_devices"] = nd
        if calibrate_costs:
            # NOTE: cost calibration always runs at grad_accum=1 — the
            # microbatch lax.scan hides its body from HLO cost analysis
            # exactly like layer scans, and per-step math is ga-invariant.
            # memory_analysis above reflects the requested grad_accum.
            with mesh, activation_sharding(policy, sp=sp,
                                           serve=(shape.kind == "decode")):
                result["calibrated"] = calibrate(cfg, policy, shape,
                                                 grad_accum=1)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    _save(result, out_dir)
    return result


def _save(result: Dict[str, Any], out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, result["cell"] + ".json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for the chosen mesh")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--grad-accum", type=int, default=0,
                    help="0 = auto (16 for 50B+ models, else 1)")
    ap.add_argument("--sp", action="store_true",
                    help="Megatron-SP residual sharding (train cells)")
    ap.add_argument("--remat-policy", default=None,
                    choices=["full", "dots"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in (ARCH_IDS if args.arch is None else [args.arch]):
            for shape in (SHAPES if args.shape is None else [args.shape]):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        cid = cell_id(arch, shape, args.multi_pod)
        path = os.path.join(args.out, cid + ".json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    print(f"[skip] {cid}")
                    continue
        t0 = time.time()
        r = run_cell(arch, shape, args.multi_pod, out_dir=args.out,
                     grad_accum=args.grad_accum,
                     calibrate_costs=not args.multi_pod, sp=args.sp,
                     remat_policy=args.remat_policy)
        status = r["status"]
        extra = "" if status != "error" else " :: " + r["error"][:160]
        print(f"[{status}] {cid} ({time.time()-t0:.1f}s){extra}",
              flush=True)


if __name__ == "__main__":
    main()
