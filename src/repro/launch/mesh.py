"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod, 256 chips) or 2×16×16 (two pods, 512 chips).

    Axes: ``data`` carries DP/FSDP, ``model`` carries TP/EP.  The ``pod``
    axis (multi-pod) extends DP across the inter-pod DCN link — parameter
    all-gathers stay inside a pod's ICI torus; only gradient reductions
    cross pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np

    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for {shape}; have {len(devices)}. The dry-run "
            "sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import.")
    # dry-run env exposes 512 host devices; single-pod uses the first 256
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1):
    """Whatever fits the *current* device set (tests / local runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def usable_cluster_devices(num_clusters: int) -> int:
    """Largest device count that divides ``num_clusters``.

    The single source of truth for the cluster-mesh selection rule —
    ``make_cluster_mesh`` shards across exactly this many devices, and
    ``ClusterConfig.validate`` warns when it is 1 despite multiple
    devices being available.
    """
    devices = jax.device_count()
    return max(k for k in range(1, min(num_clusters, devices) + 1)
               if num_clusters % k == 0)


def make_cluster_mesh(num_clusters: int):
    """1-D ``clusters`` mesh for federated burst allocation, or ``None``.

    Uses the largest available device count that divides ``num_clusters``
    so every device owns the same (smallest possible) number of cluster
    shards.  Returns ``None`` on a single device or when no device split
    > 1 divides the clusters — the federated arithmetic then runs
    unsharded on one device (the documented fallback).
    """
    import numpy as np

    devices = jax.devices()
    d = usable_cluster_devices(num_clusters)
    if d <= 1:
        return None
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices[:d]), ("clusters",))
