"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

On the CPU container this runs reduced configs end-to-end (the full
configs are exercised by the dry-run); on a real TPU pod the same entry
point drives the production mesh — device count decides.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 100 --smoke
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.synthetic import SyntheticDataset
from repro.models.api import build_model
from repro.optim import make_optimizer
from repro.training import LoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (default on CPU)")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (restart test)")
    args = ap.parse_args()

    on_cpu = jax.default_backend() == "cpu"
    cfg = get_smoke_config(args.arch) if (args.smoke or on_cpu) \
        else get_config(args.arch)
    model = build_model(cfg)
    opt = make_optimizer(args.optimizer, learning_rate=3e-3)
    ds = SyntheticDataset(cfg, batch=args.batch, seq=args.seq, seed=0)
    lc = LoopConfig(total_steps=args.steps, checkpoint_every=25,
                    checkpoint_dir=args.ckpt, log_every=10,
                    fail_at_step=args.fail_at)

    t0 = time.time()
    train(model, opt, ds, lc,
          on_metrics=lambda s, m: print(
              f"step {s:5d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.3f}", flush=True))
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s "
          f"({cfg.param_count()/1e6:.1f}M params, "
          f"final loss {train.last_history[-1]:.4f})")


if __name__ == "__main__":
    main()
