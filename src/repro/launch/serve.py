"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Continuous-batching server loop over the selected architecture (reduced
config on CPU).  See examples/serve_lm.py for a scripted variant.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.api import build_model
from repro.serving.engine import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    on_cpu = jax.default_backend() == "cpu"
    cfg = get_smoke_config(args.arch) if on_cpu else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params,
                      ServeConfig(n_slots=args.slots,
                                  max_len=args.max_len))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(3, 12))),
                   max_new_tokens=args.new_tokens)
    done = eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(v) for v in done.values())
    print(f"{args.arch}: {len(done)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
