"""Deterministic, step-indexed synthetic token pipeline.

Properties the trainer relies on:

* **step-indexed**: ``batch_at(step)`` is a pure function of (seed, step) —
  restarting from a checkpoint at step k reproduces the exact remaining
  batch stream (bit-exact restart tests depend on this);
* **learnable**: tokens follow a noisy affine recurrence
  ``t_{i+1} = (a·t_i + b) mod V`` so small models visibly reduce loss in
  the end-to-end examples;
* **shardable**: the leading batch axis is laid out host-major so each data
  shard draws a disjoint deterministic slice.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    noise: float = 0.05  # fraction of corrupted transitions

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        V = self.cfg.vocab_size
        a, b = 31, 7  # affine recurrence parameters (coprime with V)
        t0 = rng.integers(0, V, size=(self.batch, 1))
        seqs = [t0]
        for _ in range(self.seq):
            seqs.append((a * seqs[-1] + b) % V)
        toks = np.concatenate(seqs, axis=1)  # [B, S+1]
        corrupt = rng.random((self.batch, self.seq + 1)) < self.noise
        toks = np.where(corrupt, rng.integers(0, V, toks.shape), toks)
        out = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if self.cfg.is_vlm:
            out["vision_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (self.batch, self.cfg.num_vision_tokens,
                     self.cfg.d_model)), jnp.float32)
        if self.cfg.is_encdec:
            out["frames"] = jnp.asarray(
                rng.standard_normal(
                    (self.batch, self.cfg.num_audio_frames,
                     self.cfg.d_model)), jnp.float32)
        return out
