"""deepseek-moe-16b [moe] — fine-grained 64 routed top-6 + 2 shared experts,
dense first layer [arXiv:2401.06066; hf]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,  # per-expert width (fine-grained)
    vocab_size=102400,
    moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408,
                  num_shared_experts=2),
    first_layer_dense_ff=10944,
    rope_theta=10000.0,
)
