"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,  # per-expert width
    vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8, expert_d_ff=1024),
    rope_theta=10000.0,
)
