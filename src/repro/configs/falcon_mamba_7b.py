"""falcon-mamba-7b [ssm] — Mamba-1, attention-free [arXiv:2410.05355]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    num_layers=64,
    d_model=4096,
    num_heads=1,  # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=65024,
    attention="none",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)
