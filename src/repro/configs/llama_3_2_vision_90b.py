"""llama-3.2-vision-90b [vlm] — gated cross-attn image layers every 5th
[hf:meta-llama/Llama-3.2-11B-Vision scaled]. Vision frontend is a stub:
``input_specs()`` provides precomputed patch embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_vision_tokens=1601,  # 1 tile × (40×40 patches + cls)
    rope_theta=500000.0,
)
