"""Architecture registry: the 10 assigned configs + reduced smoke variants.

``get_config(arch_id)`` returns the exact published configuration;
``get_smoke_config(arch_id)`` returns a structurally identical reduced
variant (few layers, narrow widths, tiny vocab) for CPU smoke tests.  The
full configs are exercised only through the dry-run (ShapeDtypeStruct —
no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, MoEConfig, SSMConfig

_MODULES = {
    "qwen2-0.5b": "qwen2_0_5b",
    "llama3-8b": "llama3_8b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "llama3-405b": "llama3_405b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-base": "whisper_base",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise ValueError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config: one CPU forward/train step must pass."""
    cfg = get_config(arch_id)
    updates: Dict = dict(
        d_model=64,
        num_heads=4,
        num_kv_heads=min(4, cfg.num_kv_heads),
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, 16),
        num_vision_tokens=24,
        num_audio_frames=32,
        remat=False,
    )
    if cfg.is_hybrid:
        updates["num_layers"] = cfg.hybrid_group  # one full group
    elif cfg.is_vlm:
        updates["num_layers"] = 2 * cfg.cross_attn_every  # two groups
    else:
        updates["num_layers"] = 2 if not cfg.first_layer_dense_ff else 3
    if cfg.encoder_layers:
        updates["encoder_layers"] = 2
    if cfg.moe is not None:
        # capacity_factor=8 guarantees no capacity drops at smoke scale, so
        # prefill/decode parity tests check cache math, not drop sets
        # (capacity dropping is covered by tests/test_layers.py).
        updates["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8,
            top_k=min(cfg.moe.top_k, 4), expert_d_ff=96,
            capacity_factor=8.0)
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(cfg.ssm, d_state=4, dt_rank=8)
    if cfg.first_layer_dense_ff:
        updates["first_layer_dense_ff"] = 160
    return dataclasses.replace(cfg, name=f"{cfg.name}-smoke", **updates)
