"""jamba-1.5-large-398b [hybrid] — Mamba:attn 7:1 interleave, MoE 16e top-2
[arXiv:2403.19887; hf]. 398B total / ~94B active."""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    hybrid_group=8,  # layer 0 of each group is attention, 1..7 Mamba
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=24576,
                  every_k_layers=2),
)
