"""whisper-base [audio] — enc-dec backbone; conv frontend stubbed
(``input_specs()`` provides precomputed frame embeddings)
[arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    num_layers=6,  # decoder layers
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    norm="layernorm",
    use_rope=False,  # sinusoidal absolute positions
    num_audio_frames=1500,
)
