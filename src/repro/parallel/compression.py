"""Gradient compression for cross-pod reductions (int8 quantized psum).

At 512+ chips the inter-pod DCN hop is the thinnest link in the gradient
all-reduce.  ``int8_allreduce`` quantizes each gradient leaf to int8 with
a per-leaf fp32 scale before the ``pod``-axis psum and dequantizes after
— 4× less DCN traffic for fp32 grads.  Intra-pod reductions stay full
precision (ICI is cheap).  Stochastic rounding keeps the quantizer
unbiased; an optional error-feedback buffer folds the residual into the
next step (Karimireddy et al., 2019).

Used through ``make_train_step(compress_grads=...)`` or standalone under
shard_map.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(x: jax.Array, key: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization, optionally stochastic."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def simulate_roundtrip(grads: Params, key: Optional[jax.Array] = None
                       ) -> Params:
    """Quantize→dequantize every leaf (what the wire sees), no psum.

    Useful as ``compress_grads`` in single-process tests and to measure
    the quantization-noise impact on convergence.
    """
    leaves, treedef = jax.tree.flatten(grads)
    out = []
    for i, g in enumerate(leaves):
        k = None if key is None else jax.random.fold_in(key, i)
        q, s = quantize_int8(g, k)
        out.append(dequantize_int8(q, s).astype(g.dtype))
    return jax.tree.unflatten(treedef, out)


def int8_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantized cross-replica sum (call inside shard_map).

    Implemented as all-gather of int8 payloads + per-rank fp32 scales,
    then a local dequantize-and-sum — each rank's scale travels with its
    payload (ranks cannot share a scale without an extra round-trip).
    Wire bytes: N·(size/4 + 4) vs. ~2·N·size for a ring fp32 all-reduce.
    """
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)  # [N, ...] int8
    ss = jax.lax.all_gather(scale, axis_name)  # [N]
    deq = qs.astype(jnp.float32) * ss.reshape(
        (-1,) + (1,) * (qs.ndim - 1))
    return jnp.sum(deq, axis=0)


def make_pod_compressor(mesh, error_feedback: bool = False):
    """Return ``compress(grads) -> grads`` that int8-round-trips every
    leaf, modelling the inter-pod quantized all-reduce.  With
    ``error_feedback`` the quantization residual is carried in a closure
    buffer and added before the next quantization (stateful; test-scale
    only — production would thread it through TrainState)."""
    state = {"residual": None}

    def compress(grads: Params) -> Params:
        g = grads
        if error_feedback and state["residual"] is not None:
            g = jax.tree.map(lambda a, r: a + r, g, state["residual"])
        out = simulate_roundtrip(g)
        if error_feedback:
            state["residual"] = jax.tree.map(lambda a, o: a - o, g, out)
        return out

    return compress
