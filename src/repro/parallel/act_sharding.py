"""Activation sharding constraints (logical-axis rules, MaxText-style).

GSPMD propagation alone can lose the batch sharding at reshapes whose
dims don't divide the mesh (e.g. qwen2's 14 heads on a 16-way model
axis) — observed as 120 GB fp32 attention-score all-reduces in the
un-constrained qwen2 train cell (EXPERIMENTS §Perf, iteration 1).  The
layers therefore pin down the key intermediates explicitly.

Models stay pure: the dry-run/launcher activates a context with the
current ShardingPolicy; without a context every helper is a no-op, so
CPU smoke tests and single-device runs are untouched.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_LOCAL = threading.local()


def current() -> Optional["ActivationSharding"]:
    return getattr(_LOCAL, "ctx", None)


@contextlib.contextmanager
def activation_sharding(policy, serve: bool = False, sp: bool = False):
    prev = current()
    _LOCAL.ctx = ActivationSharding(policy, serve, sp)
    try:
        yield _LOCAL.ctx
    finally:
        _LOCAL.ctx = prev


@dataclasses.dataclass
class ActivationSharding:
    policy: object  # repro.parallel.policy.ShardingPolicy
    # serve mode (single-token decode): batch stays REPLICATED so dense
    # matmuls consume the 2D-sharded (data × model) weights in place —
    # GSPMD then moves megabytes of activations instead of gathering
    # gigabytes of FSDP weight shards per token (EXPERIMENTS §Perf,
    # iteration 3).  Attention keeps batch-over-data (cache locality).
    serve: bool = False
    # Megatron-SP residual sharding (iteration 4): cuts activation temp
    # memory ~9x but raises counted collective bytes; opt-in per cell.
    sp: bool = False

    def _axes(self, role: Optional[str], dim: int, what: str):
        if role is None:
            return None
        table = {"dp": self.policy.dp, "tp": self.policy.tp}
        axes = self.policy._shardable(dim, table[role], f"act:{what}")
        if axes is None:
            return None
        return axes[0] if len(axes) == 1 else axes

    def constrain(self, x: jax.Array, roles: Sequence[Optional[str]],
                  what: str = "") -> jax.Array:
        spec = P(*[self._axes(r, d, what) for r, d in zip(roles, x.shape)])
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.policy.mesh, spec))

    def divides(self, dim: int, role: str) -> bool:
        table = {"dp": self.policy.dp, "tp": self.policy.tp}
        return dim % self.policy._axis_size(table[role]) == 0


# ----------------------------------------------------------- public API

def constrain(x: jax.Array, *roles: Optional[str], what: str = ""
              ) -> jax.Array:
    """Pin ``x``'s dims to mesh axes by role ('dp' | 'tp' | None)."""
    ctx = current()
    if ctx is None:
        return x
    return ctx.constrain(x, roles, what)


def constrain_qkv(q: jax.Array, k: jax.Array, v: jax.Array):
    """Attention heads sharding with the qwen2-style fallback.

    Prefer head-sharding over `model`; when the head count doesn't
    divide, shard the *query sequence* over `model` instead (keeps the
    O(S²) score tensor fully distributed; k/v stay batch-sharded and are
    all-gathered — cheap relative to scores).  Single-token decode
    (S == 1) keeps batch sharding only.
    """
    ctx = current()
    if ctx is None:
        return q, k, v
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    if ctx.divides(H, "tp"):
        kv_role = "tp" if ctx.divides(KV, "tp") else None
        q = ctx.constrain(q, ("dp", None, "tp", None), "q")
        k = ctx.constrain(k, ("dp", None, kv_role, None), "k")
        v = ctx.constrain(v, ("dp", None, kv_role, None), "v")
    elif S > 1 and ctx.divides(S, "tp"):
        q = ctx.constrain(q, ("dp", "tp", None, None), "q.seq")
        k = ctx.constrain(k, ("dp", None, None, None), "k.rep")
        v = ctx.constrain(v, ("dp", None, None, None), "v.rep")
    else:
        q = ctx.constrain(q, ("dp", None, None, None), "q.rep")
        k = ctx.constrain(k, ("dp", None, None, None), "k.rep")
        v = ctx.constrain(v, ("dp", None, None, None), "v.rep")
    return q, k, v


def constrain_attn_out(out: jax.Array) -> jax.Array:
    """Attention context [B, S, H, Dh] before the output projection.

    Pinned to the same layout as q (heads over model, full seq) so the
    Megatron-SP boundary stays on [B,S,D] tensors — without this the
    partitioner pushes the seq sharding into the attention backward and
    fully rematerializes fp32 score tensors (iteration 4 log).
    """
    ctx = current()
    if ctx is None:
        return out
    B, S, H, Dh = out.shape
    if ctx.divides(H, "tp"):
        return ctx.constrain(out, ("dp", None, "tp", None), "attn_out")
    if S > 1 and ctx.divides(S, "tp"):
        return ctx.constrain(out, ("dp", "tp", None, None), "attn_out.seq")
    return ctx.constrain(out, ("dp", None, None, None), "attn_out.rep")


def constrain_tokens(x: jax.Array) -> jax.Array:
    """Residual stream [B, S, D]: batch over dp (replicated in serve)."""
    ctx = current()
    if ctx is not None and ctx.serve:
        # features over data: forces partial-D matmuls + [B,F/16] psums
        # instead of per-layer weight gathers (iteration 3b).
        return constrain(x, None, None, "dp", what="resid.serve")
    if ctx is not None and x.ndim == 3 and x.shape[1] > 1             and ctx.divides(x.shape[1], "tp"):
        # Megatron-SP: residual stream sequence-sharded over `model`
        # between blocks — TP boundary all-reduces become reduce-scatter
        # + all-gather pairs and norms/elementwise run 1/|tp| wide
        # (iteration 4).
        return constrain(x, "dp", "tp", None, what="resid.sp")
    return constrain(x, "dp", None, None, what="resid")


def constrain_ff(h: jax.Array) -> jax.Array:
    """MLP hidden [B, S, F] (or [B,S,2di]): batch over dp, F over tp."""
    ctx = current()
    if ctx is not None and ctx.serve:
        return constrain(h, None, None, "tp", what="ff.serve")
    return constrain(h, "dp", None, "tp", what="ff")


def constrain_logits(x: jax.Array) -> jax.Array:
    """Logits [B, S, V]: batch over dp, vocab over tp."""
    ctx = current()
    if ctx is not None and ctx.serve:
        return constrain(x, None, None, "tp", what="logits.serve")
    return constrain(x, "dp", None, "tp", what="logits")


def constrain_expert(x: jax.Array) -> jax.Array:
    """MoE expert-major tensors [E, C, D]: experts over tp."""
    return constrain(x, "tp", None, None, what="experts")


def constrain_dispatch(d: jax.Array) -> jax.Array:
    """MoE dispatch/combine [T, E, C]: tokens over dp, experts over tp."""
    return constrain(d, "dp", "tp", None, what="dispatch")
