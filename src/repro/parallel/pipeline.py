"""Pipeline parallelism over a mesh axis (GPipe-style, shard_map).

Maps a layer stack onto ``num_stages`` mesh shards along ``axis`` (on the
production mesh: the ``pod`` axis — each pod is one stage, so only
boundary activations cross the inter-pod DCN link, the natural cut for a
2-pod 512-chip job).  Microbatches stream through stages with
``ppermute`` handoffs; the bubble is the standard (S−1)/(M+S−1) GPipe
fraction.

The default production config keeps the pod axis on DP (DESIGN §3); PP is
a config-flag alternative for deeper-than-HBM models, exercised by
``tests/test_pipeline.py`` against a single-stage oracle.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    fn: Callable[[Any, jax.Array], jax.Array],
    mesh: Mesh,
    axis: str,
    stage_params: Any,  # leaves [num_stages, ...] — one slice per stage
    x: jax.Array,  # [num_micro, micro_batch, ...] microbatched input
) -> jax.Array:
    """Stream microbatches through pipeline stages living on ``axis``.

    ``fn(stage_param_slice, microbatch) -> microbatch`` is the stage
    body; stages compose left-to-right in axis order.  Returns
    [num_micro, micro_batch, ...] — the last stage's outputs.
    """
    S = mesh.shape[axis]
    M = x.shape[0]

    def per_stage(params, xs):
        stage = jax.lax.axis_index(axis)
        params = jax.tree.map(lambda a: a[0], params)
        steps = M + S - 1
        fwd = [(i, i + 1) for i in range(S - 1)]  # stage i -> i+1

        def tick(carry, t):
            recv, outbuf = carry
            inp = jnp.where(stage == 0, xs[jnp.clip(t, 0, M - 1)], recv)
            out = fn(params, inp)
            nxt = jax.lax.ppermute(out, axis, fwd)
            done = t - (S - 1)
            write = (stage == S - 1) & (done >= 0)
            upd = outbuf.at[jnp.clip(done, 0, M - 1)].set(out)
            outbuf = jnp.where(write, upd, outbuf)
            return (nxt, outbuf), None

        init = (jnp.zeros_like(xs[0]), jnp.zeros_like(xs))
        (_, outbuf), _ = jax.lax.scan(tick, init, jnp.arange(steps))
        # broadcast the last stage's buffer to every stage
        mask = (stage == S - 1).astype(outbuf.dtype)
        return jax.lax.psum(outbuf * mask, axis)

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params),
                  P(*([None] * x.ndim))),
        out_specs=P(*([None] * x.ndim)),
        check_rep=False,
    )(stage_params, x)
