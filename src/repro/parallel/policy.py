"""Sharding policy: parameter/activation/cache PartitionSpecs per mesh.

Strategy (GSPMD logical axes):

* ``dp`` = data-parallel axes — ``("data",)`` single-pod,
  ``("pod", "data")`` multi-pod (DP spans pods; within-pod stays the
  bandwidth-rich 2D torus);
* ``model`` = tensor/expert-parallel axis.

Parameters are FSDP-sharded: every weight matrix shards its input-feature
dim over ``dp`` and its output/TP dim over ``model`` (ZeRO-3-style — an
all-gather per layer materializes weights, reduce-scatter folds grads).
Experts shard over ``model`` (EP).  Mamba channel dims shard over
``model``.

Every rule passes through :meth:`ShardingPolicy._shardable`, which *drops*
an axis that does not divide the dim and records the fallback — no config
can make the dry-run fail on divisibility (e.g. qwen2's 14 heads never
shard; its fused QKV output dim 896 does).
"""
from __future__ import annotations

import dataclasses
import logging
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

log = logging.getLogger(__name__)

AxisSpec = Optional[Tuple[str, ...]]  # names for ONE dim (None = replicate)

# symbolic per-dim axis assignment: "dp" | "tp" | None per dimension
_NAME_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "emb": ("tp", "dp"),  # [V, D]: vocab over model, features FSDP
    "unemb": ("dp", "tp"),  # [D, V]
    "wq": ("dp", "tp"), "wk": ("dp", "tp"), "wv": ("dp", "tp"),
    "wg": ("dp", "tp"), "wu": ("dp", "tp"), "w_in": ("dp", "tp"),
    "wo": ("tp", "dp"), "wd": ("tp", "dp"), "w_out": ("tp", "dp"),
    "w_dt": (None, "tp"),  # [r, di]
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    "conv_b": ("tp",), "b_dt": ("tp",), "D": ("tp",),
    "router": ("dp", None),  # [D, E] — experts dim replicated (small)
    "experts_wg": ("tp", "dp", None),  # [E, D, F]: EP + FSDP
    "experts_wu": ("tp", "dp", None),
    "experts_wd": ("tp", None, "dp"),  # [E, F, D]
    "conv_w": (None, "tp"),  # [k, di]
    "w_x": ("tp", None),  # [di, r+2n]
    "A_log": ("tp", None),  # [di, n]
}

# pytree containers whose leading dim(s) are layer stacks (scan axes)
_STACK_KEYS = ("layers", "groups", "enc_layers", "dec_layers",
               "mamba_moe", "mamba_mlp", "self")


@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh
    fallbacks: List[str] = dataclasses.field(default_factory=list)

    # ----------------------------------------------------------- helpers
    @property
    def dp(self) -> Tuple[str, ...]:
        return tuple(n for n in ("pod", "data") if n in self.mesh.axis_names)

    @property
    def tp(self) -> Tuple[str, ...]:
        return ("model",) if "model" in self.mesh.axis_names else ()

    def _axis_size(self, axes: Sequence[str]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes], initial=1))

    def _shardable(self, dim: int, axes: Sequence[str], what: str) -> AxisSpec:
        """Keep `axes` only if they divide `dim`; else fall back."""
        axes = tuple(axes)
        if not axes:
            return None
        if dim % self._axis_size(axes) == 0:
            return axes
        # try a prefix (e.g. ('pod','data') -> ('pod',))
        for cut in range(len(axes) - 1, 0, -1):
            if dim % self._axis_size(axes[:cut]) == 0:
                self.fallbacks.append(
                    f"{what}: dim {dim} % {axes} != 0 -> {axes[:cut]}")
                return axes[:cut]
        self.fallbacks.append(f"{what}: dim {dim} % {axes} != 0 -> replicated")
        return None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------------ params
    def _symbolic_axes(self, path: str, ndim: int
                       ) -> Tuple[Optional[str], ...]:
        parts = path.split("/")
        name = parts[-1]
        if name == "vr":  # adafactor row stat: param axes minus last
            return self._symbolic_axes("/".join(parts[:-1]), ndim + 1)[:-1]
        if name == "vc":  # column stat: param axes minus second-to-last
            base = self._symbolic_axes("/".join(parts[:-1]), ndim + 1)
            return base[:-2] + base[-1:]
        # adam moments' paths start with mu/nu, so the final dict key is
        # the parameter name either way.
        return _NAME_AXES.get(name, (None,) * ndim)

    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for one parameter leaf, dispatched on its name."""
        sym = self._symbolic_axes(path, len(shape))
        if len(sym) != len(shape):  # unknown name or scalar: replicate
            return P(*([None] * len(shape)))
        table = {"dp": self.dp, "tp": self.tp}
        parts = []
        for dim, s in zip(shape, sym):
            if s is None:
                parts.append(None)
            else:
                parts.append(_one(self._shardable(dim, table[s],
                                                  f"{path}[{s}]")))
        return P(*parts)

    def tree_specs(self, tree: Any) -> Any:
        """Map a pytree of arrays/ShapeDtypeStructs to PartitionSpecs.

        Layer-stacked leaves ([L, ...] from scan stacking) are detected by
        path components (layers/groups/...) and get a leading None dim.
        """
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for path, leaf in flat:
            keys = [_key_str(k) for k in path]
            pstr = "/".join(keys)
            n_stack = sum(1 for k in keys if k in _STACK_KEYS)
            shape = tuple(leaf.shape)
            core = shape[n_stack:]
            spec = self.param_spec(pstr, core) if core else P()
            parts = [None] * n_stack + list(spec)
            out.append(P(*parts))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------- activations
    def batch_spec(self, batch: Any) -> Any:
        """Input batch: leading batch dim over dp, rest replicated."""
        def one(leaf):
            b = leaf.shape[0]
            axes = self._shardable(b, self.dp, "batch")
            return P(*([_one(axes)] + [None] * (leaf.ndim - 1)))

        return jax.tree.map(one, batch)

    def cache_spec(self, cache: Any) -> Any:
        """Decode-cache sharding.

        KV caches [L, B, T, KV, Dh]: batch over dp; then prefer KV-head
        sharding over `model` when divisible, else shard the *sequence*
        dim over `model` (flash-decode style — see §Perf).  long_500k
        (B=1) spreads the sequence over every axis.  SSM states shard
        channels over `model`.
        """
        def one(path, leaf):
            keys = "/".join(_key_str(k) for k in path)
            shape = tuple(leaf.shape)
            if leaf.ndim <= 1 or "pos" in keys:
                return P(*([None] * leaf.ndim))
            if "conv" in keys:  # [L(,M), B, k, di]
                lead = leaf.ndim - 3
                return P(*([None] * lead),
                         _one(self._shardable(shape[lead], self.dp, "cacheB")),
                         None,
                         _one(self._shardable(shape[-1], self.tp, "cacheDi")))
            if "ssm" in keys:  # [L(,M), B, di, n]
                lead = leaf.ndim - 3
                return P(*([None] * lead),
                         _one(self._shardable(shape[lead], self.dp, "cacheB")),
                         _one(self._shardable(shape[-2], self.tp, "cacheDi")),
                         None)
            # kv caches: [..., B, T, KV, Dh]
            lead = leaf.ndim - 4
            B, T, KV, Dh = shape[lead:]
            b_axes = self._shardable(B, self.dp, "cacheB")
            if B == 1 and b_axes is None:
                # long_500k: no batch to shard; spread T over everything
                t_axes = self._shardable(T, self.dp + self.tp, "cacheT")
                return P(*([None] * lead), None, _one(t_axes), None, None)
            if KV % self._axis_size(self.tp) == 0:
                return P(*([None] * lead), _one(b_axes), None,
                         _one(self.tp), None)
            t_axes = self._shardable(T, self.tp, "cacheT")
            return P(*([None] * lead), _one(b_axes), _one(t_axes),
                     None, None)

        flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
        return jax.tree_util.tree_unflatten(
            treedef, [one(p, l) for p, l in flat])


def _one(axes: AxisSpec):
    if axes is None:
        return None
    return axes[0] if len(axes) == 1 else axes


def _key_str(k) -> str:
    m = re.match(r".*'(.*)'.*", str(k))
    if m:
        return m.group(1)
    return str(k).strip(".[]")
