"""Workflow arrival patterns (paper §6.1.4, Fig. 5(a-c)).

Each pattern is a builder returning a list of ``(time_seconds,
num_workflows)`` bursts, registered in ``repro.api.registry.ARRIVALS``
so scenarios select them declaratively by name (``Scenario(arrival=
"pyramid", arrival_params={...})``) and third-party patterns plug in
without edits here:

    from repro.api.registry import ARRIVALS

    @ARRIVALS.register("poisson_burst")
    def poisson_burst(lam=3.0, bursts=6, interval=300.0, seed=0): ...
"""
from __future__ import annotations

from typing import List, Tuple

from repro.api.registry import ARRIVALS

INTERVAL = 300.0


@ARRIVALS.register(
    "constant", doc="y workflows every interval, `bursts` times")
def constant(y: int = 5, bursts: int = 6, interval: float = INTERVAL
             ) -> List[Tuple[float, int]]:
    """y workflows every `interval` s, `bursts` times (5×6 = 30)."""
    return [(i * interval, y) for i in range(bursts)]


@ARRIVALS.register(
    "linear", doc="y = k·x + d rising bursts")
def linear(k: int = 2, d: int = 2, bursts: int = 5, interval: float = INTERVAL
           ) -> List[Tuple[float, int]]:
    """y = k·x + d rising bursts (2,4,6,8,10 = 30)."""
    return [(i * interval, d + k * i) for i in range(bursts)]


@ARRIVALS.register(
    "pyramid", doc="grow start→peak by `step`, shrink back, repeat")
def pyramid(start: int = 2, peak: int = 6, step: int = 2, total: int = 34,
            interval: float = INTERVAL) -> List[Tuple[float, int]]:
    """Grow start→peak by `step`, shrink back, repeat until `total` (=34).

    Produces 2,4,6,4,2,2,4,6,4 for the defaults — Σ = 34, matching §6.1.4.
    """
    out: List[Tuple[float, int]] = []
    sent, t, y, direction = 0, 0.0, start, +1
    while sent < total:
        y_emit = min(y, total - sent)
        out.append((t, y_emit))
        sent += y_emit
        t += interval
        if y >= peak:
            direction = -1
        y += direction * step
        if y < start:
            y, direction = start, +1
    return out


# Legacy name→builder view of the built-ins; the ARRIVALS registry is
# the source of truth (and the only place third-party patterns appear).
PATTERNS = {"constant": constant, "linear": linear, "pyramid": pyramid}


def total_workflows(pattern: List[Tuple[float, int]]) -> int:
    return sum(n for _, n in pattern)
