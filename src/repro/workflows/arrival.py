"""Workflow arrival patterns (paper §6.1.4, Fig. 5(a-c))."""
from __future__ import annotations

from typing import List, Tuple

# Each pattern is a list of (time_seconds, num_workflows) bursts.
INTERVAL = 300.0


def constant(y: int = 5, bursts: int = 6, interval: float = INTERVAL
             ) -> List[Tuple[float, int]]:
    """y workflows every `interval` s, `bursts` times (5×6 = 30)."""
    return [(i * interval, y) for i in range(bursts)]


def linear(k: int = 2, d: int = 2, bursts: int = 5, interval: float = INTERVAL
           ) -> List[Tuple[float, int]]:
    """y = k·x + d rising bursts (2,4,6,8,10 = 30)."""
    return [(i * interval, d + k * i) for i in range(bursts)]


def pyramid(start: int = 2, peak: int = 6, step: int = 2, total: int = 34,
            interval: float = INTERVAL) -> List[Tuple[float, int]]:
    """Grow start→peak by `step`, shrink back, repeat until `total` (=34).

    Produces 2,4,6,4,2,2,4,6,4 for the defaults — Σ = 34, matching §6.1.4.
    """
    out: List[Tuple[float, int]] = []
    sent, t, y, direction = 0, 0.0, start, +1
    while sent < total:
        y_emit = min(y, total - sent)
        out.append((t, y_emit))
        sent += y_emit
        t += interval
        if y >= peak:
            direction = -1
        y += direction * step
        if y < start:
            y, direction = start, +1
    return out


PATTERNS = {"constant": constant, "linear": linear, "pyramid": pyramid}


def total_workflows(pattern: List[Tuple[float, int]]) -> int:
    return sum(n for _, n in pattern)
