"""Workflow arrival patterns (paper §6.1.4, Fig. 5(a-c)) + stochastic ones.

Each pattern is a builder returning a list of ``(time_seconds,
num_workflows)`` bursts, registered in ``repro.api.registry.ARRIVALS``
so scenarios select them declaratively by name (``Scenario(arrival=
"pyramid", arrival_params={...})``) and third-party patterns plug in
without edits here:

    from repro.api.registry import ARRIVALS

    @ARRIVALS.register("poisson_burst")
    def poisson_burst(lam=3.0, bursts=6, interval=300.0, seed=0): ...

The paper's three deterministic patterns emit lockstep bursts at exact
``interval`` marks.  The stochastic patterns (``poisson``, ``jittered``)
model the headline scenario — "continuous workflow requests and
unexpected resource request spikes" — as per-workflow arrival streams
with no two events sharing a timestamp; pair them with a positive
``TimingConfig.batch_window`` so the engine's windowed drain folds the
jittered arrivals back into fused dispatches.  They carry the
``stochastic`` capability flag, which tells :class:`repro.api.Scenario`
to wire its own ``seed`` into the builder (so ``grid(seeds=...)`` sweeps
replicate arrivals too); ``trace`` replays an explicit timestamp list,
e.g. one recorded from a production request log.
"""
from __future__ import annotations

import itertools
from typing import Iterable, List, Sequence, Tuple, Union

import numpy as np

from repro.api.registry import ARRIVALS

INTERVAL = 300.0


@ARRIVALS.register(
    "constant", doc="y workflows every interval, `bursts` times")
def constant(y: int = 5, bursts: int = 6, interval: float = INTERVAL
             ) -> List[Tuple[float, int]]:
    """y workflows every `interval` s, `bursts` times (5×6 = 30)."""
    return [(i * interval, y) for i in range(bursts)]


@ARRIVALS.register(
    "linear", doc="y = k·x + d rising bursts")
def linear(k: int = 2, d: int = 2, bursts: int = 5, interval: float = INTERVAL
           ) -> List[Tuple[float, int]]:
    """y = k·x + d rising bursts (2,4,6,8,10 = 30)."""
    return [(i * interval, d + k * i) for i in range(bursts)]


@ARRIVALS.register(
    "pyramid", doc="grow start→peak by `step`, shrink back, repeat")
def pyramid(start: int = 2, peak: int = 6, step: int = 2, total: int = 34,
            interval: float = INTERVAL) -> List[Tuple[float, int]]:
    """Grow start→peak by `step`, shrink back, repeat until `total` (=34).

    Produces 2,4,6,4,2,2,4,6,4 for the defaults — Σ = 34, matching §6.1.4.
    """
    out: List[Tuple[float, int]] = []
    sent, t, y, direction = 0, 0.0, start, +1
    while sent < total:
        y_emit = min(y, total - sent)
        out.append((t, y_emit))
        sent += y_emit
        t += interval
        if y >= peak:
            direction = -1
        y += direction * step
        if y < start:
            y, direction = start, +1
    return out


# ------------------------------------------------------------- stochastic

def _thin(rng: np.random.Generator, rel_rate, peak: float,
          mean_total: float, horizon: float) -> List[float]:
    """Inhomogeneous Poisson sampling by conditioning + thinning.

    Draw the total count ``N ~ Poisson(mean_total)`` (``mean_total`` =
    the rate function's integral over the horizon), then rejection-
    sample ``N`` timestamps from the normalized rate density: uniform
    candidates accepted with probability ``rel_rate(t) / peak``.  One
    rng draw per candidate, in a fixed order — seed-deterministic.
    """
    n = int(rng.poisson(mean_total))
    times: List[float] = []
    while len(times) < n:
        t = float(rng.uniform(0.0, horizon))
        if float(rng.uniform()) * peak <= rel_rate(t):
            times.append(t)
    return sorted(times)


@ARRIVALS.register(
    "poisson", capabilities=("stochastic",),
    doc="Poisson stream, optionally rate-ramped, per-workflow arrivals")
def poisson(lam: float = 5.0, bursts: int = 6, interval: float = INTERVAL,
            seed: int = 0, ramp: float = 0.0) -> List[Tuple[float, int]]:
    """Poisson arrival stream with the same *average* load as
    ``constant(y=lam, bursts=bursts)``: mean rate ``lam/interval`` over
    the horizon ``[0, bursts·interval)``.

    Sampled by conditioning-and-thinning: draw the total count
    ``N ~ Poisson(∫rate)``, then thin ``N`` timestamps from the rate
    density — the exact conditional law of a Poisson process.  Each
    workflow arrives alone (bursts of size 1), so without a positive
    ``batch_window`` every arrival is its own dispatch.

    ``ramp`` makes the stream inhomogeneous: the rate climbs linearly
    from ``1`` to ``1 + ramp`` (relative) across the horizon — e.g.
    ``ramp=2.0`` ends at 3× the starting rate, ``ramp=-0.5`` decays to
    half.  The expected total becomes ``lam·bursts·(1 + ramp/2)``.
    ``ramp=0`` keeps the homogeneous sampling path byte-identical to
    previous releases (same rng draws).
    """
    if lam <= 0:
        raise ValueError(f"poisson lam must be > 0, got {lam}")
    if bursts < 1 or interval <= 0:
        raise ValueError(f"poisson needs bursts >= 1 and interval > 0, "
                         f"got bursts={bursts}, interval={interval}")
    if ramp < -1.0:
        raise ValueError(f"poisson ramp must be >= -1 (the end rate "
                         f"1 + ramp cannot go negative), got {ramp}")
    rng = np.random.default_rng(seed)
    horizon = bursts * interval
    if ramp == 0.0:
        # Homogeneous: the original two-draw path, byte for byte.
        n = int(rng.poisson(lam * bursts))
        times = np.sort(rng.uniform(0.0, horizon, n))
        return [(float(t), 1) for t in times]
    times = _thin(
        rng, lambda t: 1.0 + ramp * t / horizon,
        peak=max(1.0, 1.0 + ramp),
        mean_total=lam * bursts * (1.0 + ramp / 2.0),
        horizon=horizon,
    )
    return [(t, 1) for t in times]


@ARRIVALS.register(
    "spike", capabilities=("stochastic",),
    doc="Poisson stream with a rate spike — the overload stress input")
def spike(lam: float = 5.0, bursts: int = 6, interval: float = INTERVAL,
          spike_at: float = 0.5, spike_width: float = 0.15,
          spike_factor: float = 4.0, seed: int = 0
          ) -> List[Tuple[float, int]]:
    """Poisson stream at base rate ``lam/interval`` with a
    ``spike_factor``× rate spike over the horizon fraction
    ``[spike_at, spike_at + spike_width)`` — the paper's "unexpected
    resource request spikes" as a declarative stress input for chaos
    and backpressure scenarios.  Sampled by the same conditioning +
    thinning as the ramped ``poisson``.
    """
    if lam <= 0:
        raise ValueError(f"spike lam must be > 0, got {lam}")
    if bursts < 1 or interval <= 0:
        raise ValueError(f"spike needs bursts >= 1 and interval > 0, "
                         f"got bursts={bursts}, interval={interval}")
    if not 0.0 <= spike_at < 1.0 or spike_width <= 0 \
            or spike_at + spike_width > 1.0:
        raise ValueError(
            f"spike window must satisfy 0 <= spike_at < 1, "
            f"spike_width > 0, spike_at + spike_width <= 1, got "
            f"spike_at={spike_at}, spike_width={spike_width}")
    if spike_factor < 1.0:
        raise ValueError(f"spike_factor must be >= 1 (use ramp for "
                         f"decaying rates), got {spike_factor}")
    rng = np.random.default_rng(seed)
    horizon = bursts * interval
    lo, hi = spike_at * horizon, (spike_at + spike_width) * horizon

    def rel(t: float) -> float:
        return spike_factor if lo <= t < hi else 1.0

    times = _thin(
        rng, rel, peak=spike_factor,
        mean_total=lam * bursts * (1.0 + (spike_factor - 1.0) * spike_width),
        horizon=horizon,
    )
    return [(t, 1) for t in times]


@ARRIVALS.register(
    "jittered", capabilities=("stochastic",),
    doc="deterministic base pattern with per-workflow arrival jitter")
def jittered(base: str = "constant", jitter: float = 30.0, seed: int = 0,
             base_params: dict = None) -> List[Tuple[float, int]]:
    """Jittered variant of a deterministic pattern: every workflow of a
    base burst ``(t, n)`` arrives independently at ``t + U[0, jitter)``
    — the paper's workloads under realistic request-stream dispersion
    (constant/linear/pyramid all jitter through this one entry).
    """
    entry = ARRIVALS.get(base)
    if entry.supports("stochastic"):
        raise ValueError(
            f"jittered base must be a deterministic pattern, "
            f"got stochastic {base!r}"
        )
    if jitter < 0:
        raise ValueError(f"jitter must be >= 0, got {jitter}")
    pattern = entry.factory(**dict(base_params or {}))
    rng = np.random.default_rng(seed)
    times: List[float] = []
    for t, n in pattern:
        times.extend(float(x) for x in t + rng.uniform(0.0, jitter, n))
    return [(t, 1) for t in sorted(times)]


@ARRIVALS.register(
    "trace", doc="replay an explicit list of arrival timestamps")
def trace(times: Sequence[Union[float, Tuple[float, int]]] = ()
          ) -> List[Tuple[float, int]]:
    """Replay explicit arrival timestamps (e.g. from a request log).

    ``times`` entries are either bare timestamps (one workflow each) or
    ``(timestamp, count)`` pairs; equal timestamps coalesce into one
    burst, and the output is time-sorted regardless of input order.
    """
    flat: List[Tuple[float, int]] = []
    for item in times:
        t, n = item if isinstance(item, (tuple, list)) else (item, 1)
        if not np.isfinite(t) or t < 0:
            raise ValueError(f"trace timestamps must be finite and >= 0, "
                             f"got {t!r}")
        if n < 1 or n != int(n):
            raise ValueError(f"trace counts must be integers >= 1, "
                             f"got {n!r}")
        flat.append((float(t), int(n)))
    return [
        (t, sum(n for _, n in group))
        for t, group in itertools.groupby(sorted(flat), key=lambda p: p[0])
    ]


def total_workflows(pattern: Iterable[Tuple[float, int]]) -> int:
    return sum(n for _, n in pattern)
