"""The four scientific-workflow topologies of the paper (§6.1.2, Fig. 4).

Small-scale variants (≈20 tasks) derived from the Pegasus workflow gallery,
with virtual entrance/exit nodes added exactly as the paper does.  Task
counts match the paper: Montage 21, Epigenomics 20, CyberShake 22, LIGO 23.
Structure coverage: out-tree + fan-in (Montage), pipeline (Epigenomics),
fork-join wide/shallow (CyberShake), in-tree (LIGO).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.workflows.spec import TaskSpec, WorkflowSpec, make_task


def _build(
    workflow_id: str,
    names: List[str],
    edges: List[Tuple[str, str]],
    rng: np.random.Generator,
    task_kwargs: Optional[dict] = None,
) -> WorkflowSpec:
    kw = dict(task_kwargs or {})
    tasks: Dict[str, TaskSpec] = {}
    for name in names:
        if name in ("entrance", "exit"):
            # Virtual nodes: zero-cost bookkeeping tasks (paper §6.1.2).
            tasks[name] = TaskSpec(
                task_id=name, image="virtual", cpu=0.0, mem=0.0,
                duration=0.0, min_cpu=0.0, min_mem=0.0,
            )
        else:
            tasks[name] = make_task(name, rng, **kw)
    return WorkflowSpec(workflow_id=workflow_id, tasks=tasks, edges=edges)


def montage(workflow_id: str, rng: np.random.Generator,
            task_kwargs: Optional[dict] = None) -> WorkflowSpec:
    """21 tasks — out-tree into fan-in chains (Fig. 4(a))."""
    proj = [f"mProject_{i}" for i in range(4)]
    diff = [f"mDiffFit_{i}" for i in range(5)]
    tail = ["mConcatFit", "mBgModel"]
    bg = [f"mBackground_{i}" for i in range(4)]
    post = ["mImgtbl", "mAdd", "mShrink", "mJPEG"]
    names = ["entrance"] + proj + diff + tail + bg + post + ["exit"]
    assert len(names) == 21

    edges: List[Tuple[str, str]] = [("entrance", p) for p in proj]
    # overlapping project pairs feed the difference fits
    for i, d in enumerate(diff):
        edges.append((proj[i % 4], d))
        edges.append((proj[(i + 1) % 4], d))
    edges += [(d, "mConcatFit") for d in diff]
    edges.append(("mConcatFit", "mBgModel"))
    edges += [("mBgModel", b) for b in bg]
    edges += [(b, "mImgtbl") for b in bg]
    edges += [("mImgtbl", "mAdd"), ("mAdd", "mShrink"), ("mShrink", "mJPEG"),
              ("mJPEG", "exit")]
    return _build(workflow_id, names, edges, rng, task_kwargs)


def epigenomics(workflow_id: str, rng: np.random.Generator,
                task_kwargs: Optional[dict] = None) -> WorkflowSpec:
    """20 tasks — four parallel 4-stage pipelines (Fig. 4(b))."""
    stages = ["filterContams", "sol2sanger", "fastq2bfq", "map"]
    names = ["entrance", "fastqSplit"]
    edges: List[Tuple[str, str]] = [("entrance", "fastqSplit")]
    for lane in range(4):
        prev = "fastqSplit"
        for s in stages:
            name = f"{s}_{lane}"
            names.append(name)
            edges.append((prev, name))
            prev = name
        edges.append((prev, "mapMerge"))
    names += ["mapMerge", "exit"]
    edges.append(("mapMerge", "exit"))
    assert len(names) == 20
    return _build(workflow_id, names, edges, rng, task_kwargs)


def cybershake(workflow_id: str, rng: np.random.Generator,
               task_kwargs: Optional[dict] = None) -> WorkflowSpec:
    """22 tasks — wide, shallow fork-join (Fig. 4(c))."""
    extract = [f"ExtractSGT_{i}" for i in range(2)]
    synth = [f"SeisSynth_{i}" for i in range(15)]
    peak = [f"PeakValCalc_{i}" for i in range(2)]
    zips = ["ZipSeis"]
    names = ["entrance"] + extract + synth + peak + zips + ["exit"]
    assert len(names) == 22

    edges: List[Tuple[str, str]] = [("entrance", e) for e in extract]
    for i, s in enumerate(synth):
        edges.append((extract[i % 2], s))
        edges.append((s, peak[i % 2]))
        edges.append((s, "ZipSeis"))
    edges += [(p, "exit") for p in peak]
    edges.append(("ZipSeis", "exit"))
    return _build(workflow_id, names, edges, rng, task_kwargs)


def ligo(workflow_id: str, rng: np.random.Generator,
         task_kwargs: Optional[dict] = None) -> WorkflowSpec:
    """23 tasks — two concurrent in-trees (Fig. 4(d))."""
    tmplt = [f"TmpltBank_{i}" for i in range(8)]
    insp = [f"Inspiral_{i}" for i in range(8)]
    trig = [f"TrigBank_{i}" for i in range(2)]
    thinca = [f"Thinca_{i}" for i in range(2)]
    names = ["entrance"] + tmplt + insp + trig + thinca + ["Coire", "exit"]
    assert len(names) == 23

    edges: List[Tuple[str, str]] = [("entrance", t) for t in tmplt]
    edges += [(tmplt[i], insp[i]) for i in range(8)]
    for i, t in enumerate(trig):  # fan-in 4:1
        edges += [(insp[4 * i + j], t) for j in range(4)]
    edges += [(trig[i], thinca[i]) for i in range(2)]
    edges += [(t, "Coire") for t in thinca]
    edges.append(("Coire", "exit"))
    return _build(workflow_id, names, edges, rng, task_kwargs)


WORKFLOW_BUILDERS: Dict[str, Callable[..., WorkflowSpec]] = {
    "montage": montage,
    "epigenomics": epigenomics,
    "cybershake": cybershake,
    "ligo": ligo,
}
