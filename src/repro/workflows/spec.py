"""Workflow specifications: DAGs of TaskSpecs (paper §3.1, Eq. 1-4)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import TaskSpec


@dataclasses.dataclass
class WorkflowSpec:
    """w_i = {sla, s_1..s_n} with edges encoding task dependencies."""

    workflow_id: str
    tasks: Dict[str, TaskSpec]
    edges: List[Tuple[str, str]]  # (parent, child)
    deadline: Optional[float] = None  # sla_{w_i} (Eq. 3)

    def __post_init__(self):
        names = set(self.tasks)
        for a, b in self.edges:
            if a not in names or b not in names:
                raise ValueError(f"edge ({a},{b}) references unknown task")
        self._check_acyclic()

    # --------------------------------------------------------------- graph
    def parents(self, task_id: str) -> List[str]:
        return [a for a, b in self.edges if b == task_id]

    def children(self, task_id: str) -> List[str]:
        return [b for a, b in self.edges if a == task_id]

    def indegrees(self) -> Dict[str, int]:
        deg = {t: 0 for t in self.tasks}
        for _, b in self.edges:
            deg[b] += 1
        return deg

    def roots(self) -> List[str]:
        return [t for t, d in self.indegrees().items() if d == 0]

    def topological_order(self) -> List[str]:
        deg = self.indegrees()
        ready = sorted([t for t, d in deg.items() if d == 0])
        order: List[str] = []
        while ready:
            t = ready.pop(0)
            order.append(t)
            for c in self.children(t):
                deg[c] -= 1
                if deg[c] == 0:
                    ready.append(c)
            ready.sort()
        if len(order) != len(self.tasks):
            raise ValueError("cycle detected")
        return order

    def _check_acyclic(self) -> None:
        self.topological_order()

    # ------------------------------------------------------------ schedule
    def earliest_starts(self, t0: float = 0.0) -> Dict[str, float]:
        """Critical-path earliest start times (planning-phase knowledge).

        The MAPE-K Plan step uses these projections as the ``t_start`` of
        not-yet-launched tasks in the knowledge base, so Alg. 1 can see
        *future* in-window competitors (paper Fig. 1: T2-T4 inside T1's
        lifecycle).
        """
        est: Dict[str, float] = {}
        for t in self.topological_order():
            ps = self.parents(t)
            if not ps:
                est[t] = t0
            else:
                est[t] = max(est[p] + self.tasks[p].duration for p in ps)
        return est

    @property
    def num_tasks(self) -> int:
        return len(self.tasks)

    def critical_path_length(self) -> float:
        est = self.earliest_starts()
        return max(est[t] + self.tasks[t].duration for t in self.tasks)


def make_task(
    task_id: str,
    rng: np.random.Generator,
    *,
    cpu: float = 2000.0,
    mem: float = 4000.0,
    min_cpu: float = 100.0,
    min_mem: float = 1000.0,
    dur_range: Tuple[float, float] = (10.0, 20.0),
    actual_min_mem: Optional[float] = None,
    usage_curve: Optional[str] = None,
    usage_params: Tuple[Tuple[str, object], ...] = (),
) -> TaskSpec:
    """Paper §6.1.3 instantiation: requests=limits=2000m/4000Mi, Stress
    holds 1000Mi (= min_mem), duration ~ U(10, 20) s.

    ``usage_curve``/``usage_params`` optionally attach an ARC-V usage
    model (see ``repro.vertical``) so actual consumption diverges from
    the admitted quota; ``repro.vertical.attach_usage`` stamps these onto
    an existing spec wholesale.
    """
    return TaskSpec(
        task_id=task_id,
        image="task-emulator:stress",
        cpu=cpu,
        mem=mem,
        duration=float(rng.uniform(*dur_range)),
        min_cpu=min_cpu,
        min_mem=min_mem,
        actual_min_mem=actual_min_mem,
        usage_curve=usage_curve,
        usage_params=usage_params,
    )
