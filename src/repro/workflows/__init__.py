from repro.workflows.arrival import PATTERNS, constant, linear, pyramid
from repro.workflows.dags import (
    WORKFLOW_BUILDERS,
    cybershake,
    epigenomics,
    ligo,
    montage,
)
from repro.workflows.spec import TaskSpec, WorkflowSpec, make_task

__all__ = [
    "PATTERNS", "constant", "linear", "pyramid",
    "WORKFLOW_BUILDERS", "montage", "epigenomics", "cybershake", "ligo",
    "TaskSpec", "WorkflowSpec", "make_task",
]
