from repro.workflows.arrival import (
    constant,
    jittered,
    linear,
    poisson,
    pyramid,
    spike,
    trace,
)
from repro.workflows.dags import (
    WORKFLOW_BUILDERS,
    cybershake,
    epigenomics,
    ligo,
    montage,
)
from repro.workflows.spec import TaskSpec, WorkflowSpec, make_task

__all__ = [
    "constant", "linear", "pyramid", "poisson", "jittered", "spike",
    "trace",
    "WORKFLOW_BUILDERS", "montage", "epigenomics", "cybershake", "ligo",
    "TaskSpec", "WorkflowSpec", "make_task",
]
