"""Pluggable pod-placement policies, branchless for the fused dispatch.

The paper delegates placement to the default K8s scheduler; the seed
hard-coded worst-fit (max-residual-CPU node, mirroring ARAS's orientation
toward the max-residual node, Alg. 1 lines 19-22).  Placement is a
policy selected via ``AllocatorConfig.placement`` and resolved through
the ``repro.api.registry.PLACEMENTS`` registry — third-party policies
register a score function with one decorator and no edits here:

    from repro.api.registry import PLACEMENTS

    @PLACEMENTS.register("most_free_mem")
    def _most_free_mem(res_cpu, res_mem, cpu, mem, cap_cpu, cap_mem):
        return res_mem

Built-ins:

* ``worst_fit``  — max residual CPU among fitting nodes (seed behaviour;
  spreads load, keeps the max-residual node large for ARAS scaling)
* ``best_fit``   — min residual CPU among fitting nodes (packs tightly,
  preserves large holes for big requests)
* ``first_fit``  — lowest node index that fits (cheapest mental model,
  matches kube-scheduler's score-less fallback)
* ``balanced``   — kube-scheduler NodeResourcesFit least-allocated score:
  the mean of the post-placement free CPU and memory *fractions*
  ``((res−req)/cap)``, so a node with slack in both dimensions beats one
  maxed out on either.  Carries the ``needs_capacity_view`` capability
  flag: per-node allocatable capacities are required.

Each policy reduces to ``argmax`` over a per-node *key* — the policy
score where the pod fits, ``-inf`` elsewhere — so the choice compiles
into the fused allocation dispatch with no host round-trip and no
data-dependent branching.  ``placement_key`` is shape-polymorphic: the
allocator's sequential core evaluates it over ``[num_blocks, lane]``
residual tiles (two-stage block argmax on CPU/TPU-scan, flat min-index
argmax inside the Pallas kernel — identical results, since max/compare
are exact).  Ties resolve to the lowest node index (argmax-first
semantics), identical to the seed's ``np.argmax``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.registry import PLACEMENTS

# Fit slack mirroring the seed's ``_best_node_for`` epsilon.
_FIT_EPS = 1e-6


def _node_index(residual_cpu: jax.Array) -> jax.Array:
    """Flat node index per entry, whatever the tile shape ([m] or [nb, L])."""
    if residual_cpu.ndim == 1:
        return jnp.arange(residual_cpu.shape[0], dtype=jnp.int32)
    nb, lane = residual_cpu.shape
    blk = jax.lax.broadcasted_iota(jnp.int32, (nb, lane), 0)
    off = jax.lax.broadcasted_iota(jnp.int32, (nb, lane), 1)
    return blk * lane + off


# Built-in score functions.  Signature contract (all registered
# policies): (residual_cpu, residual_mem, cpu, mem, cap_cpu, cap_mem) →
# per-node score, shape-polymorphic over [m] and [nb, lane] tiles, as
# jnp expressions only (the score is traced inside the fused dispatch
# and the Pallas sequential core alike).

@PLACEMENTS.register(
    "worst_fit",
    doc="max residual CPU among fitting nodes (seed behaviour)")
def _worst_fit(residual_cpu, residual_mem, cpu, mem, cap_cpu, cap_mem):
    return residual_cpu


@PLACEMENTS.register(
    "best_fit",
    doc="min residual CPU among fitting nodes (packs tightly)")
def _best_fit(residual_cpu, residual_mem, cpu, mem, cap_cpu, cap_mem):
    return -residual_cpu


@PLACEMENTS.register(
    "first_fit",
    doc="lowest node index that fits (kube score-less fallback)")
def _first_fit(residual_cpu, residual_mem, cpu, mem, cap_cpu, cap_mem):
    # Strictly decreasing in the index: argmax = first fitting node.
    return -_node_index(residual_cpu).astype(residual_cpu.dtype)


@PLACEMENTS.register(
    "balanced",
    capabilities=("needs_capacity_view",),
    doc="kube NodeResourcesFit least-allocated: mean post-placement "
        "free fraction")
def _balanced(residual_cpu, residual_mem, cpu, mem, cap_cpu, cap_mem):
    # Guard capacities so padding lanes (or an empty node) cannot poison
    # the key with inf/nan — they are excluded by ``fits`` anyway.
    safe_ccpu = jnp.maximum(cap_cpu, _FIT_EPS)
    safe_cmem = jnp.maximum(cap_mem, _FIT_EPS)
    return 0.5 * (
        (residual_cpu - cpu) / safe_ccpu + (residual_mem - mem) / safe_cmem
    )


# Registered policy names (registry is the source of truth; kept as a
# module constant for parametrized tests and benchmark axes).
PLACEMENT_POLICIES = PLACEMENTS.names()


def placement_key(
    policy: str,
    residual_cpu: jax.Array,
    residual_mem: jax.Array,
    cpu: jax.Array,
    mem: jax.Array,
    cap_cpu: Optional[jax.Array] = None,
    cap_mem: Optional[jax.Array] = None,
) -> jax.Array:
    """Per-node argmax key for one (cpu, mem) quota: score or ``-inf``.

    Works on flat ``[m]`` residuals and on ``[nb, lane]`` tiles alike
    (padding entries must carry large-negative residuals so they never
    fit).  Policies flagged ``needs_capacity_view`` (e.g. ``balanced``)
    require ``cap_cpu``/``cap_mem`` (allocatable capacity, same shape as
    the residuals).
    """
    entry = PLACEMENTS.get(policy)  # actionable ValueError on a typo
    if entry.supports("needs_capacity_view") and \
            (cap_cpu is None or cap_mem is None):
        raise ValueError(
            f"placement policy {policy!r} needs per-node allocatable "
            f"capacities (cap_cpu/cap_mem)"
        )
    fits = (residual_cpu >= cpu - _FIT_EPS) & (residual_mem >= mem - _FIT_EPS)
    score = entry.factory(residual_cpu, residual_mem, cpu, mem,
                          cap_cpu, cap_mem)
    return jnp.where(fits, score, -jnp.inf)


def pick_node(
    residual_cpu: jax.Array,
    residual_mem: jax.Array,
    cpu: jax.Array,
    mem: jax.Array,
    policy: str,
    cap_cpu: Optional[jax.Array] = None,
    cap_mem: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Choose a host for a (cpu, mem) quota; vmap/scan-safe.

    Returns ``(node, fits_any)`` where ``node`` is the policy's argmax over
    fitting nodes (0 when nothing fits — callers must gate on ``fits_any``).
    """
    key = placement_key(policy, residual_cpu, residual_mem, cpu, mem,
                        cap_cpu, cap_mem)
    node = jnp.argmax(key).astype(jnp.int32)
    return node, key[node] > -jnp.inf
