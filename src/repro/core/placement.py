"""Pluggable pod-placement policies, branchless for the fused dispatch.

The paper delegates placement to the default K8s scheduler; the seed
hard-coded worst-fit (max-residual-CPU node, mirroring ARAS's orientation
toward the max-residual node, Alg. 1 lines 19-22).  Placement is now a
policy selected via ``EngineConfig.placement``:

* ``worst_fit``  — max residual CPU among fitting nodes (seed behaviour;
  spreads load, keeps the max-residual node large for ARAS scaling)
* ``best_fit``   — min residual CPU among fitting nodes (packs tightly,
  preserves large holes for big requests)
* ``first_fit``  — lowest node index that fits (cheapest mental model,
  matches kube-scheduler's score-less fallback)

Each policy reduces to ``argmax(where(fits, score, -inf))`` over a
per-node score, so the choice compiles into the single fused allocation
dispatch with no host round-trip and no data-dependent branching.  Ties
resolve to the lowest node index (argmax-first semantics), identical to
the seed's ``np.argmax``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# Fit slack mirroring the seed's ``_best_node_for`` epsilon.
_FIT_EPS = 1e-6

PLACEMENT_POLICIES = ("worst_fit", "best_fit", "first_fit")


def placement_score(policy: str, residual_cpu: jax.Array) -> jax.Array:
    """Per-node score whose argmax (over fitting nodes) picks the pod host."""
    if policy == "worst_fit":
        return residual_cpu
    if policy == "best_fit":
        return -residual_cpu
    if policy == "first_fit":
        # Strictly decreasing in the index: argmax = first fitting node.
        return -jnp.arange(residual_cpu.shape[0], dtype=residual_cpu.dtype)
    raise ValueError(
        f"unknown placement policy {policy!r} (want one of {PLACEMENT_POLICIES})"
    )


def pick_node(
    residual_cpu: jax.Array,
    residual_mem: jax.Array,
    cpu: jax.Array,
    mem: jax.Array,
    policy: str,
) -> Tuple[jax.Array, jax.Array]:
    """Choose a host for a (cpu, mem) quota; vmap/scan-safe.

    Returns ``(node, fits_any)`` where ``node`` is the policy's argmax over
    fitting nodes (0 when nothing fits — callers must gate on ``fits_any``).
    """
    fits = (residual_cpu >= cpu - _FIT_EPS) & (residual_mem >= mem - _FIT_EPS)
    score = placement_score(policy, residual_cpu)
    node = jnp.argmax(jnp.where(fits, score, -jnp.inf)).astype(jnp.int32)
    return node, jnp.any(fits)
