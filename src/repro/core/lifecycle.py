"""Lifecycle concurrency window — Algorithm 1 lines 4-13, vectorized.

``request.cpu`` / ``request.mem`` accumulate the declared requests of every
task whose start time falls inside the current task's lifecycle window
``[t_start, t_end)`` — the set of pods that will *compete* with the current
request (paper Fig. 1).  The Go original iterates the Redis task map; here
it is one masked reduction.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import TaskWindow


@jax.jit
def _window_demand(
    t_start: jax.Array,
    cpu: jax.Array,
    mem: jax.Array,
    done: jax.Array,
    window_start: jax.Array,
    window_end: jax.Array,
    own_cpu: jax.Array,
    own_mem: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    # Alg.1 line 9: task.t_start ∈ [task_req.t_start, task_req.t_end).
    in_window = (t_start >= window_start) & (t_start < window_end) & (~done)
    w = in_window.astype(cpu.dtype)
    req_cpu = own_cpu + jnp.sum(cpu * w)
    req_mem = own_mem + jnp.sum(mem * w)
    return req_cpu, req_mem


def window_demand(
    window: TaskWindow,
    window_start: float,
    window_end: float,
    own_cpu: float,
    own_mem: float,
) -> Tuple[float, float]:
    """Total in-window demand including the requesting task itself.

    Alg. 1 lines 5-6 seed the accumulator with the current task's own
    request; lines 8-13 add every not-yet-done record whose start lies in
    the window.
    """
    if window.t_start.shape[0] == 0:
        return float(own_cpu), float(own_mem)
    req_cpu, req_mem = _window_demand(
        jnp.asarray(window.t_start, jnp.float32),
        jnp.asarray(window.cpu, jnp.float32),
        jnp.asarray(window.mem, jnp.float32),
        jnp.asarray(window.done),
        jnp.float32(window_start),
        jnp.float32(window_end),
        jnp.float32(own_cpu),
        jnp.float32(own_mem),
    )
    return float(req_cpu), float(req_mem)
