"""Lifecycle concurrency window — Algorithm 1 lines 4-13, vectorized.

``request.cpu`` / ``request.mem`` accumulate the declared requests of every
task whose start time falls inside the current task's lifecycle window
``[t_start, t_end)`` — the set of pods that will *compete* with the current
request (paper Fig. 1).  The Go original iterates the Redis task map; here
it is one masked reduction.

Entry points sharing one masked kernel:

* :func:`masked_demand` — traced scalar helper; a task's record is
  excluded by slot index (the knowledge base keeps every record,
  including the requester's own).
* :func:`masked_demand_batch` — its vmapped ``[B, T]`` form.  The fused
  burst allocator (``repro.core.allocator``) calls it inside its
  precompute to hoist every row's *base* demand (record table at
  pre-burst start times) out of the sequential core; mid-burst
  ``t_start`` stamps are folded back in via a ``[B, B]`` correction
  table, so each accepted allocation stays visible to later rows.
* :func:`window_demand` — legacy scalar API (one task, pre-filtered
  window), kept for ``MapeK`` / ``mljobs`` / direct callers.
* :func:`window_demand_batch` — jitted host-facing wrapper of the
  batched form.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import TaskWindow


def masked_demand(
    rec_t_start: jax.Array,  # [T] f32
    rec_cpu: jax.Array,  # [T] f32
    rec_mem: jax.Array,  # [T] f32
    rec_done: jax.Array,  # [T] bool
    slot_ids: jax.Array,  # [T] int32 (arange; hoisted so scans reuse it)
    window_start: jax.Array,  # scalar f32
    window_end: jax.Array,  # scalar f32
    own_cpu: jax.Array,  # scalar f32
    own_mem: jax.Array,  # scalar f32
    self_slot: jax.Array,  # scalar int32; -1 = no own record to exclude
) -> Tuple[jax.Array, jax.Array]:
    """Alg. 1 lines 5-13: own request + Σ in-window competitor requests.

    Alg.1 line 9: competitor.t_start ∈ [window_start, window_end) and not
    yet complete.  ``self_slot`` masks the requester's own knowledge-base
    record (the seed filtered it out host-side, rebuilding the arrays per
    request; masking keeps the array view persistent).
    """
    in_window = (rec_t_start >= window_start) & (rec_t_start < window_end) & (
        ~rec_done
    )
    w = (in_window & (slot_ids != self_slot)).astype(rec_cpu.dtype)
    req_cpu = own_cpu + jnp.sum(rec_cpu * w)
    req_mem = own_mem + jnp.sum(rec_mem * w)
    return req_cpu, req_mem


@jax.jit
def _window_demand(
    t_start: jax.Array,
    cpu: jax.Array,
    mem: jax.Array,
    done: jax.Array,
    window_start: jax.Array,
    window_end: jax.Array,
    own_cpu: jax.Array,
    own_mem: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    slot_ids = jnp.arange(t_start.shape[0], dtype=jnp.int32)
    return masked_demand(
        t_start, cpu, mem, done, slot_ids, window_start, window_end,
        own_cpu, own_mem, jnp.int32(-1),
    )


# Batched form: [B] windows × [T] records in one dispatch — the mask is a
# [B, T] matrix reduced along the record axis.  Shared-window terms
# broadcast; per-task terms batch on the leading axis.  ``masked_demand_batch``
# is the *traceable* form: the fused burst allocator calls it inside its own
# jit to hoist the whole burst's base demand out of the sequential scan
# (one [B, T] reduction instead of B per-step [T] reductions).
masked_demand_batch = jax.vmap(
    masked_demand,
    in_axes=(None, None, None, None, None, None, 0, 0, 0, 0),
)

_window_demand_batch = jax.jit(masked_demand_batch)


def window_demand(
    window: TaskWindow,
    window_start: float,
    window_end: float,
    own_cpu: float,
    own_mem: float,
) -> Tuple[float, float]:
    """Total in-window demand including the requesting task itself.

    Alg. 1 lines 5-6 seed the accumulator with the current task's own
    request; lines 8-13 add every not-yet-done record whose start lies in
    the window.
    """
    if window.t_start.shape[0] == 0:
        return float(own_cpu), float(own_mem)
    req_cpu, req_mem = _window_demand(
        jnp.asarray(window.t_start, jnp.float32),
        jnp.asarray(window.cpu, jnp.float32),
        jnp.asarray(window.mem, jnp.float32),
        jnp.asarray(window.done),
        jnp.float32(window_start),
        jnp.float32(window_end),
        jnp.float32(own_cpu),
        jnp.float32(own_mem),
    )
    return float(req_cpu), float(req_mem)


def window_demand_batch(
    window: TaskWindow,
    window_start: float,
    window_ends,
    own_cpu,
    own_mem,
    self_slots=None,
) -> Tuple[jax.Array, jax.Array]:
    """In-window demand for a burst of B tasks against one record table.

    ``window_ends`` / ``own_cpu`` / ``own_mem`` are [B] arrays; the
    optional ``self_slots`` ([B] int32) excludes each task's own record by
    slot index (-1 = nothing to exclude).  Returns ([B], [B]) demands.
    """
    ends = jnp.asarray(window_ends, jnp.float32)
    own_c = jnp.asarray(own_cpu, jnp.float32)
    own_m = jnp.asarray(own_mem, jnp.float32)
    slots = (
        jnp.full(ends.shape, -1, jnp.int32)
        if self_slots is None
        else jnp.asarray(self_slots, jnp.int32)
    )
    if window.t_start.shape[0] == 0:
        return own_c, own_m
    slot_ids = jnp.arange(window.t_start.shape[0], dtype=jnp.int32)
    return _window_demand_batch(
        jnp.asarray(window.t_start, jnp.float32),
        jnp.asarray(window.cpu, jnp.float32),
        jnp.asarray(window.mem, jnp.float32),
        jnp.asarray(window.done),
        slot_ids,
        jnp.float32(window_start),
        ends,
        own_c,
        own_m,
        slots,
    )
