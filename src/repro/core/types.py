"""Core datatypes for the ARAS control plane.

The paper's system model (§3) uses two resource kinds: CPU (compressible)
and memory (incompressible).  We keep that pair everywhere but treat the
*unit system* as opaque — the same structures carry (millicores, MiB) for
the faithful K8s reproduction and (chip-milliseconds, HBM MiB) for the
TPU-pod workload mode.

Array-of-struct layouts are used at the engine level (readable), and
struct-of-array snapshots (`ClusterSnapshot`, `TaskWindow`) at the JAX
level so the allocation math vectorizes over nodes / pods.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import numpy as np


class PodPhase(enum.IntEnum):
    """K8s pod lifecycle phases tracked by the simulator (paper §5.2)."""

    PENDING = 0
    RUNNING = 1
    SUCCEEDED = 2
    FAILED = 3
    OOM_KILLED = 4
    DELETED = 5

    @property
    def consumes_resources(self) -> bool:
        # Alg. 2 line 8: Running and Pending pods count against a node.
        return self in (PodPhase.PENDING, PodPhase.RUNNING)


@dataclasses.dataclass(frozen=True)
class Resources:
    """A (cpu, mem) pair. cpu is compressible, mem is incompressible."""

    cpu: float
    mem: float

    def __add__(self, other: "Resources") -> "Resources":
        return Resources(self.cpu + other.cpu, self.mem + other.mem)

    def __sub__(self, other: "Resources") -> "Resources":
        return Resources(self.cpu - other.cpu, self.mem - other.mem)

    def scale(self, f: float) -> "Resources":
        return Resources(self.cpu * f, self.mem * f)

    def fits_in(self, other: "Resources") -> bool:
        return self.cpu <= other.cpu and self.mem <= other.mem

    def nonneg(self) -> bool:
        return self.cpu >= 0 and self.mem >= 0


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One workflow task s_{i,j} (paper Eq. 1).

    ``cpu``/``mem`` are the user-declared request; ``min_cpu``/``min_mem``
    the minimum viable allocation; ``duration`` the Stress-driven runtime;
    ``deadline`` the per-task SLO (Eq. 3).  ``actual_min_mem`` models what
    the task program *really* needs at runtime — §6.2.2 fine-tunes
    ``min_mem`` below it to provoke OOMKilled.

    ``usage_curve``/``usage_params`` (ARC-V) name a registered usage-curve
    model in ``repro.vertical`` describing how the task's *actual*
    consumption evolves over its lifetime as a fraction of the declared
    request — the signal the vertical controller resizes against.
    ``usage_params`` is a sorted tuple of ``(name, value)`` pairs so the
    spec stays hashable; ``None`` means consumption equals the admitted
    quota for the whole lifetime (today's model).
    """

    task_id: str
    image: str
    cpu: float
    mem: float
    duration: float
    min_cpu: float
    min_mem: float
    deadline: Optional[float] = None
    actual_min_mem: Optional[float] = None  # runtime truth; defaults to min_mem
    usage_curve: Optional[str] = None  # CURVES registry name (repro.vertical)
    usage_params: Tuple[Tuple[str, object], ...] = ()

    @property
    def request(self) -> Resources:
        return Resources(self.cpu, self.mem)

    @property
    def minimum(self) -> Resources:
        return Resources(self.min_cpu, self.min_mem)

    def runtime_min_mem(self) -> float:
        return self.min_mem if self.actual_min_mem is None else self.actual_min_mem


@dataclasses.dataclass(frozen=True)
class ClusterSnapshot:
    """Struct-of-arrays Informer view consumed by the JAX algorithms.

    ``allocatable_*``: per-node allocatable capacity (Alg. 2 lines 15-17).
    ``pod_*``: one entry per tracked pod; ``pod_active`` marks
    Running/Pending pods (Alg. 2 line 8), ``pod_node`` the hosting node.
    """

    allocatable_cpu: np.ndarray  # [m] float32
    allocatable_mem: np.ndarray  # [m] float32
    pod_node: np.ndarray  # [p] int32, index into nodes
    pod_cpu: np.ndarray  # [p] float32, request quota
    pod_mem: np.ndarray  # [p] float32, request quota
    pod_active: np.ndarray  # [p] bool

    @property
    def num_nodes(self) -> int:
        return int(self.allocatable_cpu.shape[0])

    @staticmethod
    def empty(num_nodes: int) -> "ClusterSnapshot":
        z = np.zeros((0,), np.float32)
        return ClusterSnapshot(
            allocatable_cpu=np.zeros((num_nodes,), np.float32),
            allocatable_mem=np.zeros((num_nodes,), np.float32),
            pod_node=np.zeros((0,), np.int32),
            pod_cpu=z,
            pod_mem=z,
            pod_active=np.zeros((0,), bool),
        )


@dataclasses.dataclass(frozen=True)
class TaskWindow:
    """State-store view for Alg. 1 lines 4-13 (lifecycle concurrency).

    One entry per task record in the knowledge base (Redis analogue):
    start time, declared request, completion flag.
    """

    t_start: np.ndarray  # [t] float32
    cpu: np.ndarray  # [t] float32
    mem: np.ndarray  # [t] float32
    done: np.ndarray  # [t] bool  (flag == true in Eq. 8)


@dataclasses.dataclass(frozen=True)
class TaskBatch:
    """Struct-of-arrays view of one arrival burst of ready task requests.

    One row per task, in decision order (pending retries first in FIFO
    admission order, then newly-ready tasks in event order).  ``self_slot``
    is each task's slot in the knowledge-base array view (-1 when the task
    has no record to exclude, e.g. the legacy scalar path where callers
    pre-filter the window).  ``pending`` marks retry-queue rows, which keep
    the seed's head-of-line discipline: once one pending row fails, later
    pending rows are skipped, not attempted.
    """

    cpu: np.ndarray  # [B] float32 declared request
    mem: np.ndarray  # [B] float32
    min_cpu: np.ndarray  # [B] float32 acceptance floor (Alg. 1 line 27)
    min_mem: np.ndarray  # [B] float32
    window_end: np.ndarray  # [B] float32 lifecycle window end per task
    self_slot: np.ndarray  # [B] int32 slot in the record table, -1 = none
    pending: np.ndarray  # [B] bool — retry-queue row (head-of-line rules)

    @property
    def size(self) -> int:
        return int(self.cpu.shape[0])

    @staticmethod
    def from_tasks(tasks, now, self_slots=None, pending=None) -> "TaskBatch":
        """Build a batch from TaskSpecs; window ends follow Alg. 1
        ([now, now + duration) bounded by the task deadline)."""
        ends = [
            min(now + t.duration, t.deadline)
            if t.deadline is not None else now + t.duration
            for t in tasks
        ]
        n = len(tasks)
        return TaskBatch(
            cpu=np.array([t.cpu for t in tasks], np.float32),
            mem=np.array([t.mem for t in tasks], np.float32),
            min_cpu=np.array([t.min_cpu for t in tasks], np.float32),
            min_mem=np.array([t.min_mem for t in tasks], np.float32),
            window_end=np.array(ends, np.float32),
            self_slot=np.full((n,), -1, np.int32) if self_slots is None
            else np.asarray(self_slots, np.int32),
            pending=np.zeros((n,), bool) if pending is None
            else np.asarray(pending, bool),
        )


@dataclasses.dataclass(frozen=True)
class BatchAllocation:
    """Result of one fused burst decision — one row per TaskBatch row.

    ``attempted`` is False for pending rows skipped by head-of-line
    blocking (the engine keeps them queued without counting a wait).
    ``scenario`` holds Alg. 3 scenario codes (0-3) or ``FCFS_SCENARIO``.
    """

    cpu: np.ndarray  # [B] float32 granted quota
    mem: np.ndarray  # [B] float32
    node: np.ndarray  # [B] int32 target node, -1 if nothing fits
    feasible: np.ndarray  # [B] bool — accepted (gate + placement)
    attempted: np.ndarray  # [B] bool
    scenario: np.ndarray  # [B] int32

    @property
    def size(self) -> int:
        return int(self.cpu.shape[0])

    @staticmethod
    def empty() -> "BatchAllocation":
        return BatchAllocation(
            cpu=np.zeros((0,), np.float32),
            mem=np.zeros((0,), np.float32),
            node=np.zeros((0,), np.int32),
            feasible=np.zeros((0,), bool),
            attempted=np.zeros((0,), bool),
            scenario=np.zeros((0,), np.int32),
        )


@dataclasses.dataclass(frozen=True)
class Allocation:
    """Result of one ARAS / baseline decision."""

    cpu: float
    mem: float
    node: int  # target node index, -1 if no placement found
    feasible: bool  # meets Alg.1 line-27 minimum-resource acceptance
    # Diagnostics (which Alg.3 scenario fired) — for tests and tracing.
    scenario: str = ""

    @property
    def resources(self) -> Resources:
        return Resources(self.cpu, self.mem)


# Experience constants from the paper (§5.1, §5.3, Table 1).
DEFAULT_ALPHA = 0.8  # single-node saturation guard, α ∈ (0,1)
DEFAULT_BETA = 20.0  # memory headroom above min_mem, β ≥ 20 (MiB)
