"""MAPE-K adaptive loop (paper §4.3, Fig. 3).

The loop binds the four phases to concrete components:

    Monitor  — an Informer-style snapshot provider + the knowledge base
    Analyse  — the Resource Evaluator (Alg. 3) via the allocator
    Plan     — the accepted Allocation (vertical-scaling plan)
    Execute  — a launch callback (Containerized Executor)
    Knowledge— the task-state store (Redis analogue)

It is deliberately thin: the engine (``repro.engine``) drives it per task
request; the self-healing path (OOMKilled → reallocate → relaunch, paper
§6.2.2) re-enters the same loop with the *runtime* minimum so the second
pass allocates enough memory — exactly Fig. 9's Reallocation marker.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.types import Allocation, ClusterSnapshot, TaskSpec, TaskWindow


@dataclasses.dataclass
class MapeK:
    monitor: Callable[[], ClusterSnapshot]  # Informer snapshot
    knowledge: Callable[[], TaskWindow]  # Redis-backed task records
    analyser: object  # AdaptiveAllocator | FCFSAllocator
    execute: Callable[[TaskSpec, Allocation], None]

    def step(self, task: TaskSpec, now: float) -> Optional[Allocation]:
        """One M-A-P-E cycle for a task-pod resource request.

        Returns the executed allocation, or None when the Plan was
        rejected (engine re-queues the request — paper Alg. 1 loop).
        """
        snapshot = self.monitor()  # Monitor
        window = self.knowledge()  # Knowledge
        plan = self.analyser.allocate(task, snapshot, window, now)  # Analyse+Plan
        if not plan.feasible:
            return None
        self.execute(task, plan)  # Execute
        return plan

    def heal(self, task: TaskSpec, now: float) -> Optional[Allocation]:
        """Self-healing re-entry after OOMKilled (paper §6.2.2).

        The reallocation honours the task's *runtime* memory floor — the
        knowledge base has learned the true requirement from the OOM event
        — so the relaunched pod cannot OOM on the same boundary again
        provided the cluster can ever satisfy it.
        """
        learned = dataclasses.replace(
            task, min_mem=max(task.min_mem, task.runtime_min_mem())
        )
        return self.step(learned, now)
