"""Allocator front-ends: ARAS (Algorithm 1) and the FCFS baseline.

``AdaptiveAllocator`` composes the three modules of the Resource Manager
(paper Fig. 2): Resource Discovery (Alg. 2), the lifecycle window +
summaries (Alg. 1), and the Resource Evaluator (Alg. 3).  The baseline
(``FCFSAllocator``) reproduces the paper's §6.1.6 comparison strategy: it
allocates the *full* declared request if some node can host it, otherwise
reports infeasible so the engine queues the task until resources free up.

The allocation unit is the **burst**, not the task: ``allocate_batch``
decides a whole batch of ready requests in one fused JAX dispatch.  A
``lax.scan`` walks the batch in admission order so each accepted
allocation debits node residuals and marks its knowledge-base record as
started *before* the next task is evaluated — sequentially consistent
with the paper's one-task-at-a-time loop (gated by the parity suite in
``tests/test_batch_parity.py``).  The per-request loop body is:

    window demand (Alg. 1 lines 4-13, masked reduction)
    → cluster summary (Alg. 1 lines 15-23 over the carried residuals)
    → Resource Evaluator (Alg. 3 branchless lattice)
    → acceptance gate (Alg. 1 line 27)
    → pluggable placement (worst_fit | best_fit | first_fit)

The scalar ``allocate`` API is the same kernel at batch size 1, so there
is exactly one decision path; it also means one host↔device round trip
per *burst* instead of the seed's ~3 per task.

Batch and record-table lengths are padded to power-of-two buckets so JIT
caches stay warm as the knowledge base grows (padding rows carry
``attempt=False`` / ``done=True`` and are numerically inert).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import discovery, lifecycle
from repro.core.evaluation import (
    FCFS_SCENARIO,
    SCENARIO_NAMES,
    EvalInputs,
    evaluate,
)
from repro.core.placement import pick_node
from repro.core.types import (
    DEFAULT_ALPHA,
    DEFAULT_BETA,
    Allocation,
    BatchAllocation,
    ClusterSnapshot,
    TaskBatch,
    TaskSpec,
    TaskWindow,
)


def _pow2(n: int) -> int:
    """Smallest power of two ≥ max(n, 1) — the JIT shape bucket."""
    return 1 << max(n - 1, 0).bit_length()


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "policy", "mode")
)
def _fused_burst(
    residual_cpu: jax.Array,  # [m] f32 per-node residuals (Alg. 2 output)
    residual_mem: jax.Array,  # [m] f32
    rec_t_start: jax.Array,  # [T] f32 knowledge-base record table
    rec_cpu: jax.Array,  # [T] f32
    rec_mem: jax.Array,  # [T] f32
    rec_done: jax.Array,  # [T] bool
    b_cpu: jax.Array,  # [B] f32 batch rows, admission order
    b_mem: jax.Array,  # [B] f32
    b_min_cpu: jax.Array,  # [B] f32
    b_min_mem: jax.Array,  # [B] f32
    b_wend: jax.Array,  # [B] f32 lifecycle window ends
    b_self: jax.Array,  # [B] int32 record slot to exclude, -1 = none
    b_attempt: jax.Array,  # [B] bool (False = padding row)
    b_pending: jax.Array,  # [B] bool (retry-queue row: head-of-line rules)
    now: jax.Array,  # scalar f32
    *,
    alpha: float,
    beta: float,
    policy: str,
    mode: str,
):
    """One dispatch for a whole burst: discover→window→evaluate→place.

    The scan carry holds (node residuals, record start times, head-of-line
    flag).  Accepting a request debits its quota from the chosen node and
    stamps its record's ``t_start = now`` — exactly the state transitions
    the engine performs between two per-task decisions — so step *i+1*
    observes the cluster precisely as the sequential loop would.
    """
    num_slots = rec_t_start.shape[0]
    slot_ids = jnp.arange(num_slots, dtype=jnp.int32)

    def step(carry, row):
        res_cpu, res_mem, t_start, blocked = carry
        cpu, mem, min_cpu, min_mem, wend, self_slot, attempt_in, pending = row
        # Head-of-line: once a pending row fails, later pending rows are
        # skipped (the seed's retry loop breaks at the first failure).
        attempt = attempt_in & ~(pending & blocked)
        if mode == "aras":
            # Alg. 1 lines 4-13: in-window accumulated demand.
            req_cpu, req_mem = lifecycle.masked_demand(
                t_start, rec_cpu, rec_mem, rec_done, slot_ids,
                now, wend, cpu, mem, self_slot,
            )
            # Alg. 1 lines 15-23: totals + max-residual node.
            tot_cpu = jnp.sum(res_cpu)
            tot_mem = jnp.sum(res_mem)
            imax = jnp.argmax(res_cpu)
            result = evaluate(
                EvalInputs(
                    task_cpu=cpu,
                    task_mem=mem,
                    request_cpu=req_cpu,
                    request_mem=req_mem,
                    total_residual_cpu=tot_cpu,
                    total_residual_mem=tot_mem,
                    re_max_cpu=res_cpu[imax],
                    re_max_mem=res_mem[imax],
                ),
                alpha,
            )
            alloc_cpu, alloc_mem = result.cpu, result.mem
            scenario = result.scenario
            # Alg. 1 line 27 acceptance gate.
            ok = (alloc_cpu >= min_cpu) & (alloc_mem >= min_mem + beta)
        else:  # fcfs: full declared request, placement-only feasibility
            alloc_cpu, alloc_mem = cpu, mem
            scenario = jnp.int32(FCFS_SCENARIO)
            ok = jnp.bool_(True)

        node, fits_any = pick_node(res_cpu, res_mem, alloc_cpu, alloc_mem,
                                   policy)
        accept = attempt & ok & fits_any
        debit = accept.astype(res_cpu.dtype)
        res_cpu = res_cpu.at[node].add(-alloc_cpu * debit)
        res_mem = res_mem.at[node].add(-alloc_mem * debit)
        # mark_started: the accepted record now competes at its actual
        # start time, visible to every later request in the burst.
        started = accept & (self_slot >= 0)
        slot = jnp.clip(self_slot, 0, num_slots - 1)
        t_start = t_start.at[slot].set(
            jnp.where(started, now, t_start[slot])
        )
        blocked = blocked | (pending & attempt & ~(ok & fits_any))
        out = (
            alloc_cpu,
            alloc_mem,
            jnp.where(fits_any, node, jnp.int32(-1)),
            accept,
            attempt,
            scenario,
        )
        return (res_cpu, res_mem, t_start, blocked), out

    init = (residual_cpu, residual_mem, rec_t_start, jnp.bool_(False))
    rows = (b_cpu, b_mem, b_min_cpu, b_min_mem, b_wend, b_self, b_attempt,
            b_pending)
    _, outs = jax.lax.scan(step, init, rows)
    return outs


def _pad_1d(arr: np.ndarray, size: int, fill) -> np.ndarray:
    if arr.shape[0] == size:
        return arr
    out = np.full((size,), fill, dtype=arr.dtype)
    out[: arr.shape[0]] = arr
    return out


def _dispatch_burst(
    batch: TaskBatch,
    residual_cpu,
    residual_mem,
    window: TaskWindow,
    now: float,
    *,
    alpha: float,
    beta: float,
    policy: str,
    mode: str,
) -> BatchAllocation:
    """Pad to shape buckets, run the fused kernel, sync back **once**."""
    n = batch.size
    if n == 0:
        return BatchAllocation.empty()
    nb = _pow2(n)
    nt = _pow2(window.t_start.shape[0])
    attempt = _pad_1d(np.ones((n,), bool), nb, False)
    outs = _fused_burst(
        jnp.asarray(residual_cpu, jnp.float32),
        jnp.asarray(residual_mem, jnp.float32),
        # Padding records are complete zero-demand rows: numerically inert.
        jnp.asarray(_pad_1d(np.asarray(window.t_start, np.float32), nt, 0.0)),
        jnp.asarray(_pad_1d(np.asarray(window.cpu, np.float32), nt, 0.0)),
        jnp.asarray(_pad_1d(np.asarray(window.mem, np.float32), nt, 0.0)),
        jnp.asarray(_pad_1d(np.asarray(window.done, bool), nt, True)),
        jnp.asarray(_pad_1d(batch.cpu, nb, 0.0)),
        jnp.asarray(_pad_1d(batch.mem, nb, 0.0)),
        jnp.asarray(_pad_1d(batch.min_cpu, nb, 0.0)),
        jnp.asarray(_pad_1d(batch.min_mem, nb, 0.0)),
        jnp.asarray(_pad_1d(batch.window_end, nb, 0.0)),
        jnp.asarray(_pad_1d(batch.self_slot, nb, -1)),
        jnp.asarray(attempt),
        jnp.asarray(_pad_1d(batch.pending, nb, False)),
        jnp.float32(now),
        alpha=alpha,
        beta=beta,
        policy=policy,
        mode=mode,
    )
    # The one host↔device sync of the whole burst.
    cpu, mem, node, feasible, attempted, scenario = jax.device_get(outs)
    return BatchAllocation(
        cpu=cpu[:n],
        mem=mem[:n],
        node=node[:n],
        feasible=feasible[:n],
        attempted=attempted[:n],
        scenario=scenario[:n],
    )


def allocation_at(result: BatchAllocation, i: int) -> Allocation:
    """Row ``i`` of a batch result as a scalar ``Allocation``."""
    return Allocation(
        cpu=float(result.cpu[i]),
        mem=float(result.mem[i]),
        node=int(result.node[i]),
        feasible=bool(result.feasible[i]),
        scenario=SCENARIO_NAMES[int(result.scenario[i])],
    )


@dataclasses.dataclass
class AdaptiveAllocator:
    """ARAS — Algorithm 1, burst-at-a-time.

    ``allocate_batch`` runs the paper's ``for each task pod's resource
    request`` loop as one fused scan; rows rejected by the line-27
    acceptance gate come back ``feasible=False`` and the engine re-queues
    them until a cluster-state change — identical to the paper's blocking
    behaviour.  ``allocate`` is the same kernel at batch size 1.
    """

    alpha: float = DEFAULT_ALPHA
    beta: float = DEFAULT_BETA
    placement: str = "worst_fit"

    name: str = "aras"
    mode = "aras"

    def allocate_batch(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
    ) -> BatchAllocation:
        return _dispatch_burst(
            batch, residual_cpu, residual_mem, window, now,
            alpha=self.alpha, beta=self.beta, policy=self.placement,
            mode=self.mode,
        )

    def allocate(
        self,
        task: TaskSpec,
        snapshot: ClusterSnapshot,
        window: TaskWindow,
        now: float,
    ) -> Allocation:
        # Monitor (Alg. 2) for callers holding a raw snapshot; the engine's
        # hot path hands residuals straight from its incremental cache.
        residual_cpu, residual_mem = discovery.discover(snapshot)
        result = self.allocate_batch(
            TaskBatch.from_tasks([task], now), residual_cpu, residual_mem,
            window, now,
        )
        return allocation_at(result, 0)


@dataclasses.dataclass
class FCFSAllocator:
    """Baseline (§6.1.6): first-come-first-serve full-request allocation.

    No lifecycle look-ahead, no scaling: the task gets exactly its declared
    request when some node has room, else it waits for other pods to
    release resources.
    """

    placement: str = "worst_fit"

    name: str = "fcfs"
    mode = "fcfs"

    def allocate_batch(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
    ) -> BatchAllocation:
        return _dispatch_burst(
            batch, residual_cpu, residual_mem, window, now,
            alpha=0.0, beta=0.0, policy=self.placement, mode=self.mode,
        )

    def allocate(
        self,
        task: TaskSpec,
        snapshot: ClusterSnapshot,
        window: TaskWindow,
        now: float,
    ) -> Allocation:
        residual_cpu, residual_mem = discovery.discover(snapshot)
        result = self.allocate_batch(
            TaskBatch.from_tasks([task], now), residual_cpu, residual_mem,
            window, now,
        )
        return allocation_at(result, 0)


def make_allocator(name: str, **kwargs) -> AdaptiveAllocator | FCFSAllocator:
    if name == "aras":
        return AdaptiveAllocator(**kwargs)
    if name in ("fcfs", "baseline"):
        return FCFSAllocator(
            **{k: v for k, v in kwargs.items() if k == "placement"}
        )
    raise ValueError(f"unknown allocator {name!r} (want 'aras' or 'fcfs')")
