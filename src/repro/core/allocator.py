"""Allocator front-ends: ARAS (Algorithm 1) and the FCFS baseline.

``AdaptiveAllocator`` composes the three modules of the Resource Manager
(paper Fig. 2): Resource Discovery (Alg. 2), the lifecycle window +
summaries (Alg. 1), and the Resource Evaluator (Alg. 3).  The baseline
(``FCFSAllocator``) reproduces the paper's §6.1.6 comparison strategy: it
allocates the *full* declared request if some node can host it, otherwise
reports infeasible so the engine queues the task until resources free up.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import discovery, lifecycle
from repro.core.evaluation import SCENARIO_NAMES, EvalInputs, evaluate_jit
from repro.core.types import (
    DEFAULT_ALPHA,
    DEFAULT_BETA,
    Allocation,
    ClusterSnapshot,
    TaskSpec,
    TaskWindow,
)


def _best_node_for(
    residual_cpu: np.ndarray,
    residual_mem: np.ndarray,
    cpu: float,
    mem: float,
) -> int:
    """Worst-fit placement: max-residual-CPU node that fits (cpu, mem).

    The paper delegates placement to the K8s scheduler; worst-fit mirrors
    ARAS's own orientation toward the max-residual node (Alg. 1 lines
    19-22).  Returns -1 when nothing fits.
    """
    fits = (residual_cpu >= cpu - 1e-6) & (residual_mem >= mem - 1e-6)
    if not fits.any():
        return -1
    masked = np.where(fits, residual_cpu, -np.inf)
    return int(np.argmax(masked))


@dataclasses.dataclass
class AdaptiveAllocator:
    """ARAS — Algorithm 1 (one round of the per-request loop).

    The paper's ``for each task pod's resource request`` loop re-runs on
    every engine retry event; each call here is one iteration, returning
    ``feasible=False`` when the line-27 acceptance gate fails (allocation
    below ``min_cpu`` / ``min_mem + β``), in which case the engine waits
    for a cluster-state change and retries — identical to the paper's
    blocking behaviour.
    """

    alpha: float = DEFAULT_ALPHA
    beta: float = DEFAULT_BETA

    name: str = "aras"

    def allocate(
        self,
        task: TaskSpec,
        snapshot: ClusterSnapshot,
        window: TaskWindow,
        now: float,
    ) -> Allocation:
        # --- Monitor: Alg. 2 + Alg. 1 lines 15-23.
        residual_cpu, residual_mem = discovery.discover(snapshot)
        summary = discovery.summarize(residual_cpu, residual_mem)

        # --- Alg. 1 lines 4-13: in-window demand. The lifecycle window is
        # [now, now + duration) — bounded by the deadline when declared.
        window_end = now + task.duration
        if task.deadline is not None:
            window_end = min(window_end, task.deadline)
        req_cpu, req_mem = lifecycle.window_demand(
            window, now, window_end, task.cpu, task.mem
        )

        # --- Analyse/Plan: Alg. 3.
        result = evaluate_jit(
            EvalInputs(
                task_cpu=task.cpu,
                task_mem=task.mem,
                request_cpu=req_cpu,
                request_mem=req_mem,
                total_residual_cpu=summary["total_cpu"],
                total_residual_mem=summary["total_mem"],
                re_max_cpu=summary["re_max_cpu"],
                re_max_mem=summary["re_max_mem"],
            ),
            self.alpha,
        )
        alloc_cpu = float(result.cpu)
        alloc_mem = float(result.mem)
        scenario = SCENARIO_NAMES[int(result.scenario)]

        # --- Alg. 1 line 27 acceptance gate.
        feasible = (alloc_cpu >= task.min_cpu) and (
            alloc_mem >= task.min_mem + self.beta
        )

        node = _best_node_for(
            np.asarray(residual_cpu), np.asarray(residual_mem), alloc_cpu, alloc_mem
        )
        if node < 0:
            feasible = False
        return Allocation(
            cpu=alloc_cpu, mem=alloc_mem, node=node, feasible=feasible,
            scenario=scenario,
        )


@dataclasses.dataclass
class FCFSAllocator:
    """Baseline (§6.1.6): first-come-first-serve full-request allocation.

    No lifecycle look-ahead, no scaling: the task gets exactly its declared
    request when some node has room, else it waits for other pods to
    release resources.
    """

    name: str = "fcfs"

    def allocate(
        self,
        task: TaskSpec,
        snapshot: ClusterSnapshot,
        window: TaskWindow,
        now: float,
    ) -> Allocation:
        residual_cpu, residual_mem = discovery.discover(snapshot)
        node = _best_node_for(
            np.asarray(residual_cpu), np.asarray(residual_mem), task.cpu, task.mem
        )
        return Allocation(
            cpu=task.cpu,
            mem=task.mem,
            node=node,
            feasible=node >= 0,
            scenario="fcfs",
        )


def make_allocator(name: str, **kwargs) -> AdaptiveAllocator | FCFSAllocator:
    if name == "aras":
        return AdaptiveAllocator(**kwargs)
    if name in ("fcfs", "baseline"):
        return FCFSAllocator()
    raise ValueError(f"unknown allocator {name!r} (want 'aras' or 'fcfs')")
