"""Allocator front-ends: ARAS (Algorithm 1) and the FCFS baseline.

``AdaptiveAllocator`` composes the three modules of the Resource Manager
(paper Fig. 2): Resource Discovery (Alg. 2), the lifecycle window +
summaries (Alg. 1), and the Resource Evaluator (Alg. 3).  The baseline
(``FCFSAllocator``) reproduces the paper's §6.1.6 comparison strategy: it
allocates the *full* declared request if some node can host it, otherwise
reports infeasible so the engine queues the task until resources free up.

The allocation unit is the **burst**, not the task: ``allocate_batch``
decides a whole batch of ready requests in one fused JAX dispatch.  The
paper's loop is sequential by construction — each accepted allocation
must be visible to the next request — but only through three true carry
dependencies: the per-node residuals, the cluster totals and the set of
records stamped ``t_start = now`` mid-burst.  Everything else is hoisted
into a parallel precompute:

* **window demand** (Alg. 1 lines 4-13) — one ``[B, T]`` masked reduction
  over the record table at its pre-burst start times
  (``lifecycle.masked_demand_batch``), plus a ``[B, B]`` *correction
  table* whose row *i* holds what each mid-burst-stamped record adds to
  request *i*'s window versus its pre-burst contribution.  The sequential
  core folds the correction in with a triangular stamped mask — O(B) per
  step instead of O(T).
* **cluster totals** (Alg. 1 lines 15-18) — summed once per burst, then
  debited O(1) per accepted row inside the carry.

The remaining decide→debit→place recurrence runs on a pluggable backend
(``repro.kernels.alloc_scan``): a ``lax.scan`` reference, or a Pallas TPU
kernel that keeps the residual tiles resident in VMEM across the whole
burst.  Decisions are bit-for-bit identical across backends *and* against
the engine's per-task replay mode (one dispatch per decision, carry
reconstructed from the engine's incremental caches), gated by
``tests/test_batch_parity.py`` / ``tests/test_alloc_scan.py``.

Batch and record-table lengths are padded to power-of-two buckets so JIT
caches stay warm as the knowledge base grows (padding rows carry
``attempt=False`` / ``done=True`` and are numerically inert).

Federated multi-cluster mode (``repro.cluster.federation``): a
``FederatedLayout`` lays the residual/capacity tiles out cluster-major
with per-shard totals in the carry; the same precompute → sequential core
→ sync pipeline then decides one burst against K cluster shards (accepts
debit only the owning shard, the evaluator pools federation-wide
capacity), optionally with the tiles sharded across a ``clusters``
device mesh.  ``layout=None`` is the legacy single-cluster path, bit for
bit — ``tests/test_federation_parity.py`` holds the K=1 layout to it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import ALLOCATORS
from repro.cluster import federation
from repro.cluster.federation import FederatedLayout
from repro.core import discovery, lifecycle
from repro.core.evaluation import SCENARIO_NAMES
from repro.core.types import (
    DEFAULT_ALPHA,
    DEFAULT_BETA,
    Allocation,
    BatchAllocation,
    ClusterSnapshot,
    TaskBatch,
    TaskSpec,
    TaskWindow,
)
from repro.kernels.alloc_scan import alloc_scan, resolve_backend
from repro.kernels.alloc_scan.ref import RES_PAD, alloc_step


def _pow2(n: int) -> int:
    """Smallest power of two ≥ max(n, 1) — the JIT shape bucket."""
    return 1 << max(n - 1, 0).bit_length()


@functools.partial(jax.jit, static_argnames=("mode", "layout"))
def _burst_precompute(
    residual_cpu: jax.Array,  # [m] f32 per-node residuals (Alg. 2 output)
    residual_mem: jax.Array,  # [m] f32
    cap_cpu: jax.Array,  # [m] f32 allocatable capacity (balanced scoring)
    cap_mem: jax.Array,  # [m] f32
    rec_t_start: jax.Array,  # [T] f32 knowledge-base record table
    rec_cpu: jax.Array,  # [T] f32
    rec_mem: jax.Array,  # [T] f32
    rec_done: jax.Array,  # [T] bool
    b_cpu: jax.Array,  # [B] f32 batch rows, admission order
    b_mem: jax.Array,  # [B] f32
    b_wend: jax.Array,  # [B] f32 lifecycle window ends
    b_self: jax.Array,  # [B] int32 record slot to exclude, -1 = none
    now: jax.Array,  # scalar f32
    *,
    mode: str,
    layout: FederatedLayout | None = None,
):
    """Everything the sequential core does NOT need to recompute per step.

    Returns residual/capacity tiles, the O(1)-carried totals, the hoisted
    base window demand and the ``[B, B]`` stamp-correction tables.

    ``layout`` selects the federated multi-cluster tile layout (blocks
    cluster-major, per-shard totals); ``None`` is the legacy
    single-cluster path, bit for bit.
    """
    num_slots = rec_t_start.shape[0]
    num_rows = b_cpu.shape[0]
    rc2 = federation.pad_tiles_federated(residual_cpu, layout, RES_PAD)
    rm2 = federation.pad_tiles_federated(residual_mem, layout, RES_PAD)
    cc2 = federation.pad_tiles_federated(cap_cpu, layout, 0.0)
    cm2 = federation.pad_tiles_federated(cap_mem, layout, 0.0)
    # Alg. 1 lines 15-18, hoisted: one [m] reduction per burst (per shard
    # in federated mode); the core debits O(1) on every accept.
    tot_cpu = federation.shard_totals(residual_cpu, layout)
    tot_mem = federation.shard_totals(residual_mem, layout)
    if mode != "aras":
        # FCFS never reads the demand terms; stream width-1 placeholders
        # instead of dense [B, B] zero tables.
        zeros_b = jnp.zeros((num_rows,), jnp.float32)
        zeros_bb = jnp.zeros((num_rows, 1), jnp.float32)
        return (rc2, rm2, cc2, cm2, tot_cpu, tot_mem,
                zeros_b, zeros_b, zeros_bb, zeros_bb)
    # Alg. 1 lines 4-13, hoisted: in-window demand of every row against
    # the record table at its *pre-burst* start times.
    slot_ids = jnp.arange(num_slots, dtype=jnp.int32)
    base_cpu, base_mem = lifecycle.masked_demand_batch(
        rec_t_start, rec_cpu, rec_mem, rec_done, slot_ids,
        now, b_wend, b_cpu, b_mem, b_self,
    )
    # Correction tables: delta[i, j] = row j's record demand seen by row
    # i's window once j is stamped to t_start=now, minus its pre-burst
    # contribution already inside base[i].  Row j's own column and
    # slot-less rows are masked; self-exclusion (Alg. 1 line 9) carries
    # over because slots are unique within a burst.
    cs = jnp.clip(b_self, 0, num_slots - 1)
    g_cpu = rec_cpu[cs]
    g_mem = rec_mem[cs]
    g_pre = rec_t_start[cs]
    g_valid = (b_self >= 0) & ~rec_done[cs]
    not_self = b_self[None, :] != b_self[:, None]
    w_mask = g_valid[None, :] & not_self
    w_now = (now < b_wend[:, None]) & w_mask
    w_pre = ((g_pre[None, :] >= now) & (g_pre[None, :] < b_wend[:, None])
             & w_mask)
    dw = w_now.astype(jnp.float32) - w_pre.astype(jnp.float32)
    delta_cpu = g_cpu[None, :] * dw
    delta_mem = g_mem[None, :] * dw
    return (rc2, rm2, cc2, cm2, tot_cpu, tot_mem,
            base_cpu, base_mem, delta_cpu, delta_mem)


_core_dispatch = jax.jit(
    alloc_scan,
    static_argnames=("alpha", "beta", "policy", "mode", "backend"),
)


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "policy", "mode", "layout")
)
def _replay_step(
    residual_cpu, residual_mem, cap_cpu2, cap_mem2,
    tot_cpu, tot_mem, stamped, blocked,
    b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
    delta_cpu, delta_mem, b_self, b_attempt, b_pending,
    i,
    *,
    alpha, beta, policy, mode, layout=None,
):
    """One decision of the per-task replay: the shared step at row ``i``.

    The residual carry is rebuilt from the engine's live float32 caches
    (tiling and block maxima are exact), so the replay independently
    verifies that the fused core's in-scan debits and stamps track the
    host-side state transitions bit-for-bit.
    """
    rc2 = federation.pad_tiles_federated(residual_cpu, layout, RES_PAD)
    rm2 = federation.pad_tiles_federated(residual_mem, layout, RES_PAD)
    carry = (rc2, rm2, jnp.max(rc2, axis=1), tot_cpu, tot_mem,
             stamped, blocked)
    row = (b_cpu[i], b_mem[i], b_min_cpu[i], b_min_mem[i],
           base_cpu[i], base_mem[i], delta_cpu[i], delta_mem[i],
           b_self[i], b_attempt[i], b_pending[i], i)
    carry, out = alloc_step(carry, row, cap_cpu2, cap_mem2,
                            alpha=alpha, beta=beta, policy=policy, mode=mode)
    _, _, _, tot_cpu, tot_mem, stamped, blocked = carry
    return out, tot_cpu, tot_mem, stamped, blocked


def _pad_1d(arr: np.ndarray, size: int, fill) -> np.ndarray:
    if arr.shape[0] == size:
        return arr
    out = np.full((size,), fill, dtype=arr.dtype)
    out[: arr.shape[0]] = arr
    return out


def _device_inputs(
    batch: TaskBatch,
    residual_cpu,
    residual_mem,
    window: TaskWindow,
    now: float,
    cap_cpu,
    cap_mem,
):
    """Pad to shape buckets and stage the burst on device."""
    n = batch.size
    nb = _pow2(n)
    nt = _pow2(window.t_start.shape[0])
    res_c = jnp.asarray(residual_cpu, jnp.float32)
    res_m = jnp.asarray(residual_mem, jnp.float32)
    # Capacity defaults to the current residuals when the caller has no
    # capacity view (legacy snapshot-less paths); only ``balanced``
    # scoring reads it.
    cap_c = res_c if cap_cpu is None else jnp.asarray(cap_cpu, jnp.float32)
    cap_m = res_m if cap_mem is None else jnp.asarray(cap_mem, jnp.float32)
    rows = dict(
        b_cpu=jnp.asarray(_pad_1d(batch.cpu, nb, 0.0)),
        b_mem=jnp.asarray(_pad_1d(batch.mem, nb, 0.0)),
        b_min_cpu=jnp.asarray(_pad_1d(batch.min_cpu, nb, 0.0)),
        b_min_mem=jnp.asarray(_pad_1d(batch.min_mem, nb, 0.0)),
        b_wend=jnp.asarray(_pad_1d(batch.window_end, nb, 0.0)),
        b_self=jnp.asarray(_pad_1d(batch.self_slot, nb, -1)),
        b_attempt=jnp.asarray(_pad_1d(np.ones((n,), bool), nb, False)),
        b_pending=jnp.asarray(_pad_1d(batch.pending, nb, False)),
    )
    recs = dict(
        rec_t_start=jnp.asarray(
            _pad_1d(np.asarray(window.t_start, np.float32), nt, 0.0)),
        rec_cpu=jnp.asarray(
            _pad_1d(np.asarray(window.cpu, np.float32), nt, 0.0)),
        rec_mem=jnp.asarray(
            _pad_1d(np.asarray(window.mem, np.float32), nt, 0.0)),
        # Padding records are complete zero-demand rows: numerically inert.
        rec_done=jnp.asarray(_pad_1d(np.asarray(window.done, bool), nt, True)),
    )
    return res_c, res_m, cap_c, cap_m, rows, recs, jnp.float32(now)


def _dispatch_burst(
    batch: TaskBatch,
    residual_cpu,
    residual_mem,
    window: TaskWindow,
    now: float,
    *,
    alpha: float,
    beta: float,
    policy: str,
    mode: str,
    backend: str,
    cap_cpu=None,
    cap_mem=None,
    layout: FederatedLayout | None = None,
    mesh=None,
) -> BatchAllocation:
    """Precompute → sequential core → sync back **once**.

    ``layout`` runs the burst on the federated multi-cluster tile layout
    (``repro.cluster.federation``); ``mesh`` additionally lays the tiles
    out across a ``clusters`` device mesh via ``jax.sharding``.  Node
    indices are mapped back to global node ids before the result is
    returned, so callers never see the padded federated index space.
    """
    n = batch.size
    if n == 0:
        return BatchAllocation.empty()
    res_c, res_m, cap_c, cap_m, rows, recs, now32 = _device_inputs(
        batch, residual_cpu, residual_mem, window, now, cap_cpu, cap_mem
    )
    (rc2, rm2, cc2, cm2, tot_c, tot_m, base_c, base_m, dlt_c, dlt_m) = \
        _burst_precompute(
            res_c, res_m, cap_c, cap_m,
            recs["rec_t_start"], recs["rec_cpu"], recs["rec_mem"],
            recs["rec_done"],
            rows["b_cpu"], rows["b_mem"], rows["b_wend"], rows["b_self"],
            now32, mode=mode, layout=layout,
        )
    concrete_backend = resolve_backend(backend)
    if mesh is not None and concrete_backend != "pallas":
        # pallas_call has no cross-device partitioning rule (outside
        # shard_map), so the device mesh only applies to the scan
        # backend; the Pallas kernel instead keeps the whole federation
        # VMEM-resident on one device.
        rc2, rm2, cc2, cm2 = (
            federation.shard_tiles(t, mesh) for t in (rc2, rm2, cc2, cm2))
    outs = _core_dispatch(
        rc2, rm2, cc2, cm2, tot_c, tot_m,
        rows["b_cpu"], rows["b_mem"], rows["b_min_cpu"], rows["b_min_mem"],
        base_c, base_m, dlt_c, dlt_m,
        rows["b_self"], rows["b_attempt"], rows["b_pending"],
        alpha=alpha, beta=beta, policy=policy, mode=mode,
        backend=concrete_backend,
    )
    # The one host↔device sync of the whole burst.
    cpu, mem, node, feasible, attempted, scenario = jax.device_get(outs)
    return BatchAllocation(
        cpu=cpu[:n],
        mem=mem[:n],
        node=federation.global_nodes(node[:n], layout),
        feasible=feasible[:n],
        attempted=attempted[:n],
        scenario=scenario[:n],
    )


class BurstReplay:
    """Per-task replay of one drained burst — the parity reference.

    The engine (``batch_allocation=False``) decides the same burst one
    dispatch per row, rebuilding the residual carry from its own
    incremental caches between decisions, while the demand/stamp carry
    (totals, stamped mask, head-of-line flag) advances through the same
    shared step function the fused core scans.  Decisions are therefore
    bit-for-bit identical to one fused dispatch — that is precisely what
    ``tests/test_batch_parity.py`` gates.
    """

    def __init__(self, batch, residual_cpu, residual_mem, window, now,
                 cap_cpu, cap_mem, *, alpha, beta, policy, mode,
                 layout=None):
        self._params = dict(alpha=alpha, beta=beta, policy=policy, mode=mode,
                            layout=layout)
        self._layout = layout
        res_c, res_m, cap_c, cap_m, rows, recs, now32 = _device_inputs(
            batch, residual_cpu, residual_mem, window, now, cap_cpu, cap_mem
        )
        pre = _burst_precompute(
            res_c, res_m, cap_c, cap_m,
            recs["rec_t_start"], recs["rec_cpu"], recs["rec_mem"],
            recs["rec_done"],
            rows["b_cpu"], rows["b_mem"], rows["b_wend"], rows["b_self"],
            now32, mode=mode, layout=layout,
        )
        (_, _, self._cc2, self._cm2, self._tot_c, self._tot_m,
         self._base_c, self._base_m, self._dlt_c, self._dlt_m) = pre
        self._rows = rows
        num_rows = rows["b_cpu"].shape[0]
        self._stamped = jnp.zeros((num_rows,), jnp.float32)
        self._blocked = jnp.bool_(False)

    def step(self, i: int, residual_cpu, residual_mem
             ) -> Tuple[Allocation, bool]:
        """Decide row ``i`` against the engine's current residuals."""
        rows = self._rows
        out, self._tot_c, self._tot_m, self._stamped, self._blocked = \
            _replay_step(
                jnp.asarray(residual_cpu, jnp.float32),
                jnp.asarray(residual_mem, jnp.float32),
                self._cc2, self._cm2, self._tot_c, self._tot_m,
                self._stamped, self._blocked,
                rows["b_cpu"], rows["b_mem"], rows["b_min_cpu"],
                rows["b_min_mem"], self._base_c, self._base_m,
                self._dlt_c, self._dlt_m,
                rows["b_self"], rows["b_attempt"], rows["b_pending"],
                jnp.int32(i),
                **self._params,
            )
        alloc_c, alloc_m, node, accept, attempted, scenario = \
            jax.device_get(out)
        node = federation.global_nodes(np.asarray(node), self._layout)
        return (
            Allocation(
                cpu=float(alloc_c),
                mem=float(alloc_m),
                node=int(node),
                feasible=bool(accept),
                scenario=SCENARIO_NAMES[int(scenario)],
            ),
            bool(attempted),
        )


def allocation_at(result: BatchAllocation, i: int) -> Allocation:
    """Row ``i`` of a batch result as a scalar ``Allocation``."""
    return Allocation(
        cpu=float(result.cpu[i]),
        mem=float(result.mem[i]),
        node=int(result.node[i]),
        feasible=bool(result.feasible[i]),
        scenario=SCENARIO_NAMES[int(result.scenario[i])],
    )


@dataclasses.dataclass
class AdaptiveAllocator:
    """ARAS — Algorithm 1, burst-at-a-time.

    ``allocate_batch`` runs the paper's ``for each task pod's resource
    request`` loop as one fused dispatch; rows rejected by the line-27
    acceptance gate come back ``feasible=False`` and the engine re-queues
    them until a cluster-state change — identical to the paper's blocking
    behaviour.  ``allocate`` is the same pipeline at batch size 1.
    ``backend`` selects the sequential core: ``auto`` | ``scan`` |
    ``pallas`` (see ``repro.kernels.alloc_scan``).  ``layout`` federates
    the burst across cluster shards (``repro.cluster.federation``) and
    ``cluster_sharding`` governs whether those shards are additionally
    laid out across devices (``auto``/``force`` when a device count
    divides the clusters, ``off`` never); ``layout=None`` is the legacy
    single-cluster path.
    """

    alpha: float = DEFAULT_ALPHA
    beta: float = DEFAULT_BETA
    placement: str = "worst_fit"
    backend: str = "auto"
    layout: FederatedLayout | None = None
    cluster_sharding: str = "auto"

    name: str = "aras"
    mode = "aras"

    def _mesh(self):
        return federation.resolve_mesh(self.layout, self.cluster_sharding)

    def allocate_batch(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
        cap_cpu=None,
        cap_mem=None,
    ) -> BatchAllocation:
        return _dispatch_burst(
            batch, residual_cpu, residual_mem, window, now,
            alpha=self.alpha, beta=self.beta, policy=self.placement,
            mode=self.mode, backend=self.backend,
            cap_cpu=cap_cpu, cap_mem=cap_mem,
            layout=self.layout, mesh=self._mesh(),
        )

    def begin_replay(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
        cap_cpu=None,
        cap_mem=None,
    ) -> BurstReplay:
        return BurstReplay(
            batch, residual_cpu, residual_mem, window, now, cap_cpu, cap_mem,
            alpha=self.alpha, beta=self.beta, policy=self.placement,
            mode=self.mode, layout=self.layout,
        )

    def allocate(
        self,
        task: TaskSpec,
        snapshot: ClusterSnapshot,
        window: TaskWindow,
        now: float,
    ) -> Allocation:
        # Monitor (Alg. 2) for callers holding a raw snapshot; the engine's
        # hot path hands residuals straight from its incremental cache.
        residual_cpu, residual_mem = discovery.discover(snapshot)
        result = self.allocate_batch(
            TaskBatch.from_tasks([task], now), residual_cpu, residual_mem,
            window, now,
            cap_cpu=snapshot.allocatable_cpu, cap_mem=snapshot.allocatable_mem,
        )
        return allocation_at(result, 0)


@dataclasses.dataclass
class FCFSAllocator:
    """Baseline (§6.1.6): first-come-first-serve full-request allocation.

    No lifecycle look-ahead, no scaling: the task gets exactly its declared
    request when some node has room, else it waits for other pods to
    release resources.
    """

    placement: str = "worst_fit"
    backend: str = "auto"
    layout: FederatedLayout | None = None
    cluster_sharding: str = "auto"

    name: str = "fcfs"
    mode = "fcfs"

    def _mesh(self):
        return federation.resolve_mesh(self.layout, self.cluster_sharding)

    def allocate_batch(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
        cap_cpu=None,
        cap_mem=None,
    ) -> BatchAllocation:
        return _dispatch_burst(
            batch, residual_cpu, residual_mem, window, now,
            alpha=0.0, beta=0.0, policy=self.placement, mode=self.mode,
            backend=self.backend, cap_cpu=cap_cpu, cap_mem=cap_mem,
            layout=self.layout, mesh=self._mesh(),
        )

    def begin_replay(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
        cap_cpu=None,
        cap_mem=None,
    ) -> BurstReplay:
        return BurstReplay(
            batch, residual_cpu, residual_mem, window, now, cap_cpu, cap_mem,
            alpha=0.0, beta=0.0, policy=self.placement, mode=self.mode,
            layout=self.layout,
        )

    def allocate(
        self,
        task: TaskSpec,
        snapshot: ClusterSnapshot,
        window: TaskWindow,
        now: float,
    ) -> Allocation:
        residual_cpu, residual_mem = discovery.discover(snapshot)
        result = self.allocate_batch(
            TaskBatch.from_tasks([task], now), residual_cpu, residual_mem,
            window, now,
            cap_cpu=snapshot.allocatable_cpu, cap_mem=snapshot.allocatable_mem,
        )
        return allocation_at(result, 0)


# Registry entries (repro.api.registry.ALLOCATORS): the engine selects
# allocators by name and consults capability flags instead of
# string-matching — ``adaptive_scaling`` tells it to hand over the ARAS
# alpha/beta knobs; third-party allocators register the same way.

@ALLOCATORS.register(
    "aras",
    capabilities=("adaptive_scaling", "federation_aware",
                  "lifecycle_window"),
    doc="ARAS (Alg. 1): lifecycle-window demand + Alg. 3 adaptive "
        "scaling")
def _build_aras(**kwargs) -> AdaptiveAllocator:
    return AdaptiveAllocator(**kwargs)


@ALLOCATORS.register(
    "fcfs",
    aliases=("baseline",),
    capabilities=("federation_aware",),
    doc="§6.1.6 baseline: first-come-first-serve full-request allocation")
def _build_fcfs(**kwargs) -> FCFSAllocator:
    # FCFS has no scaling knobs: accept-and-drop alpha/beta so callers
    # can hand every allocator the same kwargs.
    return FCFSAllocator(
        **{k: v for k, v in kwargs.items()
           if k in ("placement", "backend", "layout", "cluster_sharding")}
    )


def make_allocator(name: str, **kwargs) -> AdaptiveAllocator | FCFSAllocator:
    """Build a registered allocator by name (see ``ALLOCATORS``)."""
    return ALLOCATORS.get(name).factory(**kwargs)
