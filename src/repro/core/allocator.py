"""Allocator front-ends: ARAS (Algorithm 1) and the FCFS baseline.

``AdaptiveAllocator`` composes the three modules of the Resource Manager
(paper Fig. 2): Resource Discovery (Alg. 2), the lifecycle window +
summaries (Alg. 1), and the Resource Evaluator (Alg. 3).  The baseline
(``FCFSAllocator``) reproduces the paper's §6.1.6 comparison strategy: it
allocates the *full* declared request if some node can host it, otherwise
reports infeasible so the engine queues the task until resources free up.

The allocation unit is the **burst**, not the task: ``allocate_batch``
decides a whole batch of ready requests in one fused JAX dispatch.  The
paper's loop is sequential by construction — each accepted allocation
must be visible to the next request — but only through three true carry
dependencies: the per-node residuals, the cluster totals and the set of
records stamped ``t_start = now`` mid-burst.  Everything else is hoisted
into a parallel precompute:

* **window demand** (Alg. 1 lines 4-13) — one ``[B, T]`` masked reduction
  over the record table at its pre-burst start times
  (``lifecycle.masked_demand_batch``), plus a ``[B, B]`` *correction
  table* whose row *i* holds what each mid-burst-stamped record adds to
  request *i*'s window versus its pre-burst contribution.  The sequential
  core folds the correction in with a triangular stamped mask — O(B) per
  step instead of O(T).
* **cluster totals** (Alg. 1 lines 15-18) — summed once per burst, then
  debited O(1) per accepted row inside the carry.

The remaining decide→debit→place recurrence runs on a pluggable backend
(``repro.kernels.alloc_scan``): a ``lax.scan`` reference, or a Pallas TPU
kernel that keeps the residual tiles resident in VMEM across the whole
burst.  Decisions are bit-for-bit identical across backends *and* against
the engine's per-task replay mode (one dispatch per decision, carry
reconstructed from the engine's incremental caches), gated by
``tests/test_batch_parity.py`` / ``tests/test_alloc_scan.py``.

Batch and record-table lengths are padded to power-of-two buckets so JIT
caches stay warm as the knowledge base grows (padding rows carry
``attempt=False`` / ``done=True`` and are numerically inert).

Federated multi-cluster mode (``repro.cluster.federation``): a
``FederatedLayout`` lays the residual/capacity tiles out cluster-major
with per-shard totals in the carry; the same precompute → sequential core
→ sync pipeline then decides one burst against K cluster shards (accepts
debit only the owning shard, the evaluator pools federation-wide
capacity), optionally with the tiles sharded across a ``clusters``
device mesh.  ``layout=None`` is the legacy single-cluster path, bit for
bit — ``tests/test_federation_parity.py`` holds the K=1 layout to it.

Device-resident incremental dispatch (``repro.cluster.device_state``):
``allocate_batch`` stages the full O(nodes) residual arrays per burst;
``allocate_batch_async`` instead decides against a
``DeviceResidualState`` whose tiles/block sums persist on device and are
maintained by dirty-tile scatter updates, so only the O(burst) rows move
per dispatch.  It returns a ``PendingBurst`` (sync deferred to
``wait()``), letting the engine overlap host event folding with the
in-flight fused dispatch.  Both paths share the hierarchical totals
reduction, so decisions stay bit-for-bit identical
(``tests/test_incremental_state.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import ALLOCATORS
from repro.cluster import device_state, federation
from repro.cluster.device_state import DeviceResidualState
from repro.cluster.federation import FederatedLayout
from repro.core import discovery, lifecycle
from repro.core.evaluation import SCENARIO_NAMES
from repro.core.types import (
    DEFAULT_ALPHA,
    DEFAULT_BETA,
    Allocation,
    BatchAllocation,
    ClusterSnapshot,
    TaskBatch,
    TaskSpec,
    TaskWindow,
)
from repro.kernels.alloc_scan import alloc_scan, resolve_backend
from repro.kernels.alloc_scan.ref import RES_PAD, alloc_step


def _pow2(n: int) -> int:
    """Smallest power of two ≥ max(n, 1) — the JIT shape bucket."""
    return 1 << max(n - 1, 0).bit_length()


@functools.partial(jax.jit, static_argnames=("mode", "layout"))
def _burst_precompute(
    residual_cpu: jax.Array,  # [m] f32 per-node residuals (Alg. 2 output)
    residual_mem: jax.Array,  # [m] f32
    cap_cpu: jax.Array,  # [m] f32 allocatable capacity (balanced scoring)
    cap_mem: jax.Array,  # [m] f32
    rec_t_start: jax.Array,  # [T] f32 knowledge-base record table
    rec_cpu: jax.Array,  # [T] f32
    rec_mem: jax.Array,  # [T] f32
    rec_done: jax.Array,  # [T] bool
    b_cpu: jax.Array,  # [B] f32 batch rows, admission order
    b_mem: jax.Array,  # [B] f32
    b_wend: jax.Array,  # [B] f32 lifecycle window ends
    b_self: jax.Array,  # [B] int32 record slot to exclude, -1 = none
    now: jax.Array,  # scalar f32
    *,
    mode: str,
    layout: FederatedLayout | None = None,
):
    """Everything the sequential core does NOT need to recompute per step.

    Returns residual/capacity tiles, the O(1)-carried totals, the hoisted
    base window demand and the ``[B, B]`` stamp-correction tables.

    ``layout`` selects the federated multi-cluster tile layout (blocks
    cluster-major, per-shard totals); ``None`` is the legacy
    single-cluster path, bit for bit.
    """
    rc2 = federation.pad_tiles_federated(residual_cpu, layout, RES_PAD)
    rm2 = federation.pad_tiles_federated(residual_mem, layout, RES_PAD)
    cc2 = federation.pad_tiles_federated(cap_cpu, layout, 0.0)
    cm2 = federation.pad_tiles_federated(cap_mem, layout, 0.0)
    # Alg. 1 lines 15-18, hoisted: one reduction per burst (per shard in
    # federated mode); the core debits O(1) on every accept.  Derived
    # hierarchically — masked per-block tile sums, then a fixed-order
    # block reduce — which is the exact reduction the device-resident
    # incremental state maintains, so the re-pad and incremental paths
    # carry bitwise-equal totals into the sequential core.
    mask2 = jnp.asarray(federation.tile_mask(residual_cpu.shape[0], layout))
    tot_cpu = federation.totals_from_block_sums(
        federation.tile_block_sums(rc2, mask2), layout)
    tot_mem = federation.totals_from_block_sums(
        federation.tile_block_sums(rm2, mask2), layout)
    base_cpu, base_mem, delta_cpu, delta_mem = _demand_tables(
        rec_t_start, rec_cpu, rec_mem, rec_done,
        b_cpu, b_mem, b_wend, b_self, now, mode=mode,
    )
    return (rc2, rm2, cc2, cm2, tot_cpu, tot_mem,
            base_cpu, base_mem, delta_cpu, delta_mem)


def _demand_tables(rec_t_start, rec_cpu, rec_mem, rec_done,
                   b_cpu, b_mem, b_wend, b_self, now, *, mode):
    """Hoisted window-demand terms, shared by both precompute entries.

    Traced inside ``_burst_precompute`` (re-pad path) and
    ``_state_dispatch`` (device-resident path) alike, so the two paths
    cannot drift.
    """
    num_slots = rec_t_start.shape[0]
    num_rows = b_cpu.shape[0]
    if mode != "aras":
        # FCFS never reads the demand terms; stream width-1 placeholders
        # instead of dense [B, B] zero tables.
        zeros_b = jnp.zeros((num_rows,), jnp.float32)
        zeros_bb = jnp.zeros((num_rows, 1), jnp.float32)
        return zeros_b, zeros_b, zeros_bb, zeros_bb
    # Alg. 1 lines 4-13, hoisted: in-window demand of every row against
    # the record table at its *pre-burst* start times.
    slot_ids = jnp.arange(num_slots, dtype=jnp.int32)
    base_cpu, base_mem = lifecycle.masked_demand_batch(
        rec_t_start, rec_cpu, rec_mem, rec_done, slot_ids,
        now, b_wend, b_cpu, b_mem, b_self,
    )
    # Correction tables: delta[i, j] = row j's record demand seen by row
    # i's window once j is stamped to t_start=now, minus its pre-burst
    # contribution already inside base[i].  Row j's own column and
    # slot-less rows are masked; self-exclusion (Alg. 1 line 9) carries
    # over because slots are unique within a burst.
    cs = jnp.clip(b_self, 0, num_slots - 1)
    g_cpu = rec_cpu[cs]
    g_mem = rec_mem[cs]
    g_pre = rec_t_start[cs]
    g_valid = (b_self >= 0) & ~rec_done[cs]
    not_self = b_self[None, :] != b_self[:, None]
    w_mask = g_valid[None, :] & not_self
    w_now = (now < b_wend[:, None]) & w_mask
    w_pre = ((g_pre[None, :] >= now) & (g_pre[None, :] < b_wend[:, None])
             & w_mask)
    dw = w_now.astype(jnp.float32) - w_pre.astype(jnp.float32)
    delta_cpu = g_cpu[None, :] * dw
    delta_mem = g_mem[None, :] * dw
    return base_cpu, base_mem, delta_cpu, delta_mem


# Slot order of the packed staging arrays used by the device-resident
# fast path.  On small bursts the staging cost is dominated by the fixed
# per-transfer dispatch overhead, not bytes, so the eight row arrays
# travel as one [8, B] float32 transfer (ints and bools ride along as
# exact float32: slot ids stay below 2**24, flags are 0/1) and the four
# record columns as one [4, T] — two host→device copies per dispatch
# instead of twelve.
_ROW_CPU, _ROW_MEM, _ROW_MIN_CPU, _ROW_MIN_MEM, _ROW_WEND, _ROW_SELF, \
    _ROW_ATTEMPT, _ROW_PENDING = range(8)
_REC_T_START, _REC_CPU, _REC_MEM, _REC_DONE = range(4)


def _fill_packed(rows: np.ndarray, recs: np.ndarray,
                 batch: TaskBatch, window: TaskWindow) -> None:
    """Fill preallocated ``[8, B]`` / ``[4, T]`` staging views in place."""
    n = batch.size
    rows[_ROW_CPU, :n] = batch.cpu
    rows[_ROW_MEM, :n] = batch.mem
    rows[_ROW_MIN_CPU, :n] = batch.min_cpu
    rows[_ROW_MIN_MEM, :n] = batch.min_mem
    rows[_ROW_WEND, :n] = batch.window_end
    rows[_ROW_SELF] = -1.0  # pad rows exclude no record slot
    rows[_ROW_SELF, :n] = batch.self_slot
    rows[_ROW_ATTEMPT, :n] = 1.0
    rows[_ROW_PENDING, :n] = batch.pending
    nrec = window.t_start.shape[0]
    recs[_REC_T_START, :nrec] = window.t_start
    recs[_REC_CPU, :nrec] = window.cpu
    recs[_REC_MEM, :nrec] = window.mem
    recs[_REC_DONE] = 1.0  # padding records are done: numerically inert
    recs[_REC_DONE, :nrec] = window.done


def _packed_row_inputs(batch: TaskBatch, window: TaskWindow, now: float):
    """``_row_inputs`` packed into two transfers, for the hot stream path."""
    rows = np.zeros((8, _pow2(batch.size)), np.float32)
    recs = np.zeros((4, _pow2(window.t_start.shape[0])), np.float32)
    _fill_packed(rows, recs, batch, window)
    return jnp.asarray(rows), jnp.asarray(recs), jnp.float32(now)


def _decide_packed(rc2, rm2, cc2, cm2, bsum_c, bsum_m, rows, recs, now,
                   *, alpha, beta, policy, mode, backend, layout):
    """Traceable device-resident decision over packed staging arrays.

    ``_burst_precompute`` minus the tiles, fused with the sequential
    core: the residual/capacity tiles already live on device
    (``repro.cluster.device_state``), the carried totals come from the
    incrementally-maintained block sums via the same fixed-order reduce
    the re-pad path uses, and the hoisted demand tables feed straight
    into ``alloc_scan`` without re-crossing a dispatch boundary.
    """
    b_cpu, b_mem = rows[_ROW_CPU], rows[_ROW_MEM]
    b_min_cpu, b_min_mem = rows[_ROW_MIN_CPU], rows[_ROW_MIN_MEM]
    b_wend = rows[_ROW_WEND]
    b_self = rows[_ROW_SELF].astype(jnp.int32)
    b_attempt = rows[_ROW_ATTEMPT] != 0
    b_pending = rows[_ROW_PENDING] != 0
    rec_done = recs[_REC_DONE] != 0
    tot_cpu = federation.totals_from_block_sums(bsum_c, layout)
    tot_mem = federation.totals_from_block_sums(bsum_m, layout)
    base_cpu, base_mem, delta_cpu, delta_mem = _demand_tables(
        recs[_REC_T_START], recs[_REC_CPU], recs[_REC_MEM], rec_done,
        b_cpu, b_mem, b_wend, b_self, now, mode=mode,
    )
    return alloc_scan(
        rc2, rm2, cc2, cm2, tot_cpu, tot_mem,
        b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
        delta_cpu, delta_mem, b_self, b_attempt, b_pending,
        alpha=alpha, beta=beta, policy=policy, mode=mode, backend=backend,
    )


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "beta", "policy", "mode", "backend", "layout"),
)
def _state_dispatch(
    rc2, rm2, cc2, cm2,  # device-resident tiles (DeviceResidualState)
    bsum_c, bsum_m,  # [nb] f32 incrementally-maintained block sums
    rows,  # [8, B] f32 packed burst rows (_ROW_* slots)
    recs,  # [4, T] f32 packed record table (_REC_* slots)
    now,  # scalar f32
    *,
    alpha, beta, policy, mode, backend,
    layout: FederatedLayout | None = None,
):
    """The device-resident decision as **one** jitted dispatch.

    Nothing O(nodes) moves, and the host pays a single call's fixed
    overhead per burst (see :func:`_decide_packed`).
    """
    return _decide_packed(
        rc2, rm2, cc2, cm2, bsum_c, bsum_m, rows, recs, now,
        alpha=alpha, beta=beta, policy=policy, mode=mode, backend=backend,
        layout=layout,
    )


def _pack_state_step(batch: TaskBatch, window: TaskWindow, now: float,
                     seg: np.ndarray):
    """Stage one maintain-and-decide step as a single flat f32 buffer.

    Layout: the dirty-set update segment (``pack_update_segment``), the
    ``[8, B]`` packed rows, the ``[4, T]`` packed record table, then the
    scalar ``now`` — one host→device copy for the whole step.
    """
    n_rows = _pow2(batch.size)
    n_rec = _pow2(window.t_start.shape[0])
    u = seg.shape[0]
    buf = np.zeros((u + 8 * n_rows + 4 * n_rec + 1,), np.float32)
    buf[:u] = seg
    rows = buf[u: u + 8 * n_rows].reshape(8, n_rows)
    recs = buf[u + 8 * n_rows: u + 8 * n_rows + 4 * n_rec].reshape(4, n_rec)
    _fill_packed(rows, recs, batch, window)
    buf[-1] = now
    return jnp.asarray(buf), n_rows, n_rec


@functools.partial(
    jax.jit,
    static_argnames=("n_idx", "n_blk", "n_rows", "n_rec",
                     "alpha", "beta", "policy", "mode", "backend", "layout"),
    # The caller hands over the pre-update tiles/block sums for good
    # (PendingBurst.state replaces them), so XLA scatters in place
    # instead of copying the whole residual tile table per step.
    donate_argnums=(0, 1, 4, 5),
)
def _state_step(
    rc2, rm2, cc2, cm2, bsum_c, bsum_m, mask2,  # DeviceResidualState
    buf,  # flat f32 staging buffer (_pack_state_step)
    *,
    n_idx, n_blk, n_rows, n_rec,
    alpha, beta, policy, mode, backend,
    layout: FederatedLayout | None = None,
):
    """Maintain **and** decide in one fused jitted dispatch.

    The streaming hot path: scatter the dirty-node deltas into the
    device-resident tiles (``repro.cluster.device_state.apply_packed``),
    re-derive the dirty block sums, then run the fused decision against
    the updated state — one host→device copy, one dispatch, per burst.
    Returns the updated ``(rc2, rm2, bsum_c, bsum_m)`` carry (device
    arrays the next step chains on without syncing) plus the decision
    outputs.  The residual tiles and block sums are **donated**: the
    input state is consumed (its buffers updated in place) and only the
    returned state is valid afterwards.  Ops are identical to
    ``apply_updates`` followed by ``_state_dispatch``, so decisions stay
    bit-for-bit with the re-pad path
    (``tests/test_incremental_state.py``).
    """
    u = 3 * n_idx + n_blk
    rc2, rm2, bsum_c, bsum_m = device_state.apply_packed(
        rc2, rm2, bsum_c, bsum_m, mask2, buf[:u], n_idx, n_blk)
    rows = buf[u: u + 8 * n_rows].reshape(8, n_rows)
    recs = buf[u + 8 * n_rows: u + 8 * n_rows + 4 * n_rec].reshape(4, n_rec)
    outs = _decide_packed(
        rc2, rm2, cc2, cm2, bsum_c, bsum_m, rows, recs, buf[-1],
        alpha=alpha, beta=beta, policy=policy, mode=mode, backend=backend,
        layout=layout,
    )
    return (rc2, rm2, bsum_c, bsum_m), outs


_core_dispatch = jax.jit(
    alloc_scan,
    static_argnames=("alpha", "beta", "policy", "mode", "backend"),
)


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "policy", "mode", "layout")
)
def _replay_step(
    residual_cpu, residual_mem, cap_cpu2, cap_mem2,
    tot_cpu, tot_mem, stamped, blocked,
    b_cpu, b_mem, b_min_cpu, b_min_mem, base_cpu, base_mem,
    delta_cpu, delta_mem, b_self, b_attempt, b_pending,
    i,
    *,
    alpha, beta, policy, mode, layout=None,
):
    """One decision of the per-task replay: the shared step at row ``i``.

    The residual carry is rebuilt from the engine's live float32 caches
    (tiling and block maxima are exact), so the replay independently
    verifies that the fused core's in-scan debits and stamps track the
    host-side state transitions bit-for-bit.
    """
    rc2 = federation.pad_tiles_federated(residual_cpu, layout, RES_PAD)
    rm2 = federation.pad_tiles_federated(residual_mem, layout, RES_PAD)
    carry = (rc2, rm2, jnp.max(rc2, axis=1), tot_cpu, tot_mem,
             stamped, blocked)
    row = (b_cpu[i], b_mem[i], b_min_cpu[i], b_min_mem[i],
           base_cpu[i], base_mem[i], delta_cpu[i], delta_mem[i],
           b_self[i], b_attempt[i], b_pending[i], i)
    carry, out = alloc_step(carry, row, cap_cpu2, cap_mem2,
                            alpha=alpha, beta=beta, policy=policy, mode=mode)
    _, _, _, tot_cpu, tot_mem, stamped, blocked = carry
    return out, tot_cpu, tot_mem, stamped, blocked


def _pad_1d(arr: np.ndarray, size: int, fill) -> np.ndarray:
    if arr.shape[0] == size:
        return arr
    out = np.full((size,), fill, dtype=arr.dtype)
    out[: arr.shape[0]] = arr
    return out


def _row_inputs(batch: TaskBatch, window: TaskWindow, now: float):
    """Pad the burst rows + record table to shape buckets and stage them.

    The O(burst)-sized half of ``_device_inputs`` — all the
    device-resident dispatch path ever stages per burst (the O(nodes)
    residual/capacity arrays stay on device across dispatches).
    """
    n = batch.size
    nb = _pow2(n)
    nt = _pow2(window.t_start.shape[0])
    rows = dict(
        b_cpu=jnp.asarray(_pad_1d(batch.cpu, nb, 0.0)),
        b_mem=jnp.asarray(_pad_1d(batch.mem, nb, 0.0)),
        b_min_cpu=jnp.asarray(_pad_1d(batch.min_cpu, nb, 0.0)),
        b_min_mem=jnp.asarray(_pad_1d(batch.min_mem, nb, 0.0)),
        b_wend=jnp.asarray(_pad_1d(batch.window_end, nb, 0.0)),
        b_self=jnp.asarray(_pad_1d(batch.self_slot, nb, -1)),
        b_attempt=jnp.asarray(_pad_1d(np.ones((n,), bool), nb, False)),
        b_pending=jnp.asarray(_pad_1d(batch.pending, nb, False)),
    )
    recs = dict(
        rec_t_start=jnp.asarray(
            _pad_1d(np.asarray(window.t_start, np.float32), nt, 0.0)),
        rec_cpu=jnp.asarray(
            _pad_1d(np.asarray(window.cpu, np.float32), nt, 0.0)),
        rec_mem=jnp.asarray(
            _pad_1d(np.asarray(window.mem, np.float32), nt, 0.0)),
        # Padding records are complete zero-demand rows: numerically inert.
        rec_done=jnp.asarray(_pad_1d(np.asarray(window.done, bool), nt, True)),
    )
    return rows, recs, jnp.float32(now)


def _device_inputs(
    batch: TaskBatch,
    residual_cpu,
    residual_mem,
    window: TaskWindow,
    now: float,
    cap_cpu,
    cap_mem,
):
    """Pad to shape buckets and stage the burst on device."""
    res_c = jnp.asarray(residual_cpu, jnp.float32)
    res_m = jnp.asarray(residual_mem, jnp.float32)
    # Capacity defaults to the current residuals when the caller has no
    # capacity view (legacy snapshot-less paths); only ``balanced``
    # scoring reads it.
    cap_c = res_c if cap_cpu is None else jnp.asarray(cap_cpu, jnp.float32)
    cap_m = res_m if cap_mem is None else jnp.asarray(cap_mem, jnp.float32)
    rows, recs, now32 = _row_inputs(batch, window, now)
    return res_c, res_m, cap_c, cap_m, rows, recs, now32


@dataclasses.dataclass
class PendingBurst:
    """A fused dispatch issued but not yet synced back to the host.

    JAX dispatch is asynchronous: once ``_core_dispatch`` returns, the
    device is computing while the host is free — so the engine can fold
    queued events (and flush dirty-tile updates into the *next* state)
    before paying the one blocking ``wait()`` sync of the burst.  The
    split is what makes the double-buffered overlap of the streaming
    engine possible; ``wait()`` is exactly the sync the one-shot path
    always did, so decisions are unaffected.
    """

    outs: tuple | None  # device arrays; None = empty burst
    n: int
    layout: FederatedLayout | None
    # Post-update device state when the dispatch also folded dirty-node
    # deltas (the fused maintain-and-decide step): valid immediately —
    # device arrays chain asynchronously — and never synced by wait().
    state: "DeviceResidualState | None" = None

    def wait(self) -> BatchAllocation:
        """Block on the device results and map nodes back to global ids."""
        if self.outs is None:
            return BatchAllocation.empty()
        # The one host↔device sync of the whole burst.
        cpu, mem, node, feasible, attempted, scenario = \
            jax.device_get(self.outs)
        n = self.n
        return BatchAllocation(
            cpu=cpu[:n],
            mem=mem[:n],
            node=federation.global_nodes(node[:n], self.layout),
            feasible=feasible[:n],
            attempted=attempted[:n],
            scenario=scenario[:n],
        )


def _issue_burst(
    batch: TaskBatch,
    residual_cpu,
    residual_mem,
    window: TaskWindow,
    now: float,
    *,
    alpha: float,
    beta: float,
    policy: str,
    mode: str,
    backend: str,
    cap_cpu=None,
    cap_mem=None,
    layout: FederatedLayout | None = None,
    mesh=None,
) -> PendingBurst:
    """Stage → precompute → sequential core; returns without syncing.

    ``layout`` runs the burst on the federated multi-cluster tile layout
    (``repro.cluster.federation``); ``mesh`` additionally lays the tiles
    out across a ``clusters`` device mesh via ``jax.sharding``.  Node
    indices are mapped back to global node ids at ``wait()``, so callers
    never see the padded federated index space.
    """
    n = batch.size
    if n == 0:
        return PendingBurst(None, 0, layout)
    res_c, res_m, cap_c, cap_m, rows, recs, now32 = _device_inputs(
        batch, residual_cpu, residual_mem, window, now, cap_cpu, cap_mem
    )
    (rc2, rm2, cc2, cm2, tot_c, tot_m, base_c, base_m, dlt_c, dlt_m) = \
        _burst_precompute(
            res_c, res_m, cap_c, cap_m,
            recs["rec_t_start"], recs["rec_cpu"], recs["rec_mem"],
            recs["rec_done"],
            rows["b_cpu"], rows["b_mem"], rows["b_wend"], rows["b_self"],
            now32, mode=mode, layout=layout,
        )
    concrete_backend = resolve_backend(backend)
    if mesh is not None and concrete_backend != "pallas":
        # pallas_call has no cross-device partitioning rule (outside
        # shard_map), so the device mesh only applies to the scan
        # backend; the Pallas kernel instead keeps the whole federation
        # VMEM-resident on one device.
        rc2, rm2, cc2, cm2 = (
            federation.shard_tiles(t, mesh) for t in (rc2, rm2, cc2, cm2))
    outs = _core_dispatch(
        rc2, rm2, cc2, cm2, tot_c, tot_m,
        rows["b_cpu"], rows["b_mem"], rows["b_min_cpu"], rows["b_min_mem"],
        base_c, base_m, dlt_c, dlt_m,
        rows["b_self"], rows["b_attempt"], rows["b_pending"],
        alpha=alpha, beta=beta, policy=policy, mode=mode,
        backend=concrete_backend,
    )
    return PendingBurst(outs, n, layout)


def _issue_state_burst(
    batch: TaskBatch,
    state,
    window: TaskWindow,
    now: float,
    *,
    alpha: float,
    beta: float,
    policy: str,
    mode: str,
    backend: str,
    updates=None,
) -> PendingBurst:
    """Issue one fused dispatch against device-resident allocator state.

    The O(nodes) staging of ``_issue_burst`` disappears: tiles and block
    sums come straight from the :class:`DeviceResidualState` the engine
    maintains by dirty-tile scatter updates; only the O(burst) rows and
    the record table cross to the device, and precompute + sequential
    core run as one fused jit call.  With ``updates`` (a
    ``(nodes, res_cpu, res_mem)`` dirty set, as drained from
    ``ClusterSim.drain_dirty``) the scatter maintenance fuses into the
    same dispatch — one flat staging buffer, one call — and the
    returned burst carries the post-update state (``PendingBurst.
    state``); the input state is **consumed** (its residual buffers are
    donated to the in-place scatter) and must not be used again.  Tile
    contents equal to what the re-pad path would build give
    bitwise-identical decisions (``tests/test_incremental_state.py``).
    """
    n = batch.size
    if n == 0:
        if updates is not None:
            state = state.apply_updates(*updates)
        return PendingBurst(None, 0, state.layout, state=state)
    if updates is None:
        rows, recs, now32 = _packed_row_inputs(batch, window, now)
        outs = _state_dispatch(
            state.rc2, state.rm2, state.cc2, state.cm2,
            state.bsum_c, state.bsum_m, rows, recs, now32,
            alpha=alpha, beta=beta, policy=policy, mode=mode,
            backend=resolve_backend(backend), layout=state.layout,
        )
        return PendingBurst(outs, n, state.layout, state=state)
    seg, n_idx, n_blk = device_state.pack_update_segment(
        updates[0], updates[1], updates[2],
        state.layout, int(state.rc2.shape[0]),
    )
    buf, n_rows, n_rec = _pack_state_step(batch, window, now, seg)
    (rc2, rm2, bsum_c, bsum_m), outs = _state_step(
        state.rc2, state.rm2, state.cc2, state.cm2,
        state.bsum_c, state.bsum_m, state.mask2, buf,
        n_idx=n_idx, n_blk=n_blk, n_rows=n_rows, n_rec=n_rec,
        alpha=alpha, beta=beta, policy=policy, mode=mode,
        backend=resolve_backend(backend), layout=state.layout,
    )
    new_state = dataclasses.replace(
        state, rc2=rc2, rm2=rm2, bsum_c=bsum_c, bsum_m=bsum_m)
    return PendingBurst(outs, n, state.layout, state=new_state)


def _dispatch_burst(*args, **kwargs) -> BatchAllocation:
    """Precompute → sequential core → sync back **once** (the one-shot
    form of ``_issue_burst``)."""
    return _issue_burst(*args, **kwargs).wait()


class BurstReplay:
    """Per-task replay of one drained burst — the parity reference.

    The engine (``batch_allocation=False``) decides the same burst one
    dispatch per row, rebuilding the residual carry from its own
    incremental caches between decisions, while the demand/stamp carry
    (totals, stamped mask, head-of-line flag) advances through the same
    shared step function the fused core scans.  Decisions are therefore
    bit-for-bit identical to one fused dispatch — that is precisely what
    ``tests/test_batch_parity.py`` gates.
    """

    def __init__(self, batch, residual_cpu, residual_mem, window, now,
                 cap_cpu, cap_mem, *, alpha, beta, policy, mode,
                 layout=None):
        self._params = dict(alpha=alpha, beta=beta, policy=policy, mode=mode,
                            layout=layout)
        self._layout = layout
        res_c, res_m, cap_c, cap_m, rows, recs, now32 = _device_inputs(
            batch, residual_cpu, residual_mem, window, now, cap_cpu, cap_mem
        )
        pre = _burst_precompute(
            res_c, res_m, cap_c, cap_m,
            recs["rec_t_start"], recs["rec_cpu"], recs["rec_mem"],
            recs["rec_done"],
            rows["b_cpu"], rows["b_mem"], rows["b_wend"], rows["b_self"],
            now32, mode=mode, layout=layout,
        )
        (_, _, self._cc2, self._cm2, self._tot_c, self._tot_m,
         self._base_c, self._base_m, self._dlt_c, self._dlt_m) = pre
        self._rows = rows
        num_rows = rows["b_cpu"].shape[0]
        self._stamped = jnp.zeros((num_rows,), jnp.float32)
        self._blocked = jnp.bool_(False)

    def step(self, i: int, residual_cpu, residual_mem
             ) -> Tuple[Allocation, bool]:
        """Decide row ``i`` against the engine's current residuals."""
        rows = self._rows
        out, self._tot_c, self._tot_m, self._stamped, self._blocked = \
            _replay_step(
                jnp.asarray(residual_cpu, jnp.float32),
                jnp.asarray(residual_mem, jnp.float32),
                self._cc2, self._cm2, self._tot_c, self._tot_m,
                self._stamped, self._blocked,
                rows["b_cpu"], rows["b_mem"], rows["b_min_cpu"],
                rows["b_min_mem"], self._base_c, self._base_m,
                self._dlt_c, self._dlt_m,
                rows["b_self"], rows["b_attempt"], rows["b_pending"],
                jnp.int32(i),
                **self._params,
            )
        alloc_c, alloc_m, node, accept, attempted, scenario = \
            jax.device_get(out)
        node = federation.global_nodes(np.asarray(node), self._layout)
        return (
            Allocation(
                cpu=float(alloc_c),
                mem=float(alloc_m),
                node=int(node),
                feasible=bool(accept),
                scenario=SCENARIO_NAMES[int(scenario)],
            ),
            bool(attempted),
        )


def allocation_at(result: BatchAllocation, i: int) -> Allocation:
    """Row ``i`` of a batch result as a scalar ``Allocation``."""
    return Allocation(
        cpu=float(result.cpu[i]),
        mem=float(result.mem[i]),
        node=int(result.node[i]),
        feasible=bool(result.feasible[i]),
        scenario=SCENARIO_NAMES[int(result.scenario[i])],
    )


@dataclasses.dataclass
class AdaptiveAllocator:
    """ARAS — Algorithm 1, burst-at-a-time.

    ``allocate_batch`` runs the paper's ``for each task pod's resource
    request`` loop as one fused dispatch; rows rejected by the line-27
    acceptance gate come back ``feasible=False`` and the engine re-queues
    them until a cluster-state change — identical to the paper's blocking
    behaviour.  ``allocate`` is the same pipeline at batch size 1.
    ``backend`` selects the sequential core: ``auto`` | ``scan`` |
    ``pallas`` (see ``repro.kernels.alloc_scan``).  ``layout`` federates
    the burst across cluster shards (``repro.cluster.federation``) and
    ``cluster_sharding`` governs whether those shards are additionally
    laid out across devices (``auto``/``force`` when a device count
    divides the clusters, ``off`` never); ``layout=None`` is the legacy
    single-cluster path.
    """

    alpha: float = DEFAULT_ALPHA
    beta: float = DEFAULT_BETA
    placement: str = "worst_fit"
    backend: str = "auto"
    layout: FederatedLayout | None = None
    cluster_sharding: str = "auto"

    name: str = "aras"
    mode = "aras"

    def _mesh(self):
        return federation.resolve_mesh(self.layout, self.cluster_sharding)

    def allocate_batch(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
        cap_cpu=None,
        cap_mem=None,
    ) -> BatchAllocation:
        return _dispatch_burst(
            batch, residual_cpu, residual_mem, window, now,
            alpha=self.alpha, beta=self.beta, policy=self.placement,
            mode=self.mode, backend=self.backend,
            cap_cpu=cap_cpu, cap_mem=cap_mem,
            layout=self.layout, mesh=self._mesh(),
        )

    def create_state(self, residual_cpu, residual_mem, cap_cpu, cap_mem
                     ) -> DeviceResidualState:
        """Stage the cluster state on device once, for the incremental
        dispatch path (``allocate_batch_async``)."""
        return DeviceResidualState.create(
            residual_cpu, residual_mem, cap_cpu, cap_mem,
            self.layout, RES_PAD,
        )

    def allocate_batch_async(
        self,
        batch: TaskBatch,
        window: TaskWindow,
        now: float,
        *,
        state: DeviceResidualState,
        updates=None,
    ) -> PendingBurst:
        """Issue one fused dispatch against device-resident state.

        Returns a :class:`PendingBurst`; the caller overlaps host work
        with the in-flight dispatch and syncs via ``wait()``.  Requires
        the ``device_state`` capability path: ``state`` plus the pending
        ``updates`` dirty set (``(nodes, res_cpu, res_mem)``, folded
        into the same dispatch; the post-update state comes back on
        ``PendingBurst.state``) must mirror the residuals
        ``allocate_batch`` would have been handed.
        """
        return _issue_state_burst(
            batch, state, window, now,
            alpha=self.alpha, beta=self.beta, policy=self.placement,
            mode=self.mode, backend=self.backend, updates=updates,
        )

    def begin_replay(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
        cap_cpu=None,
        cap_mem=None,
    ) -> BurstReplay:
        return BurstReplay(
            batch, residual_cpu, residual_mem, window, now, cap_cpu, cap_mem,
            alpha=self.alpha, beta=self.beta, policy=self.placement,
            mode=self.mode, layout=self.layout,
        )

    def allocate(
        self,
        task: TaskSpec,
        snapshot: ClusterSnapshot,
        window: TaskWindow,
        now: float,
    ) -> Allocation:
        # Monitor (Alg. 2) for callers holding a raw snapshot; the engine's
        # hot path hands residuals straight from its incremental cache.
        residual_cpu, residual_mem = discovery.discover(snapshot)
        result = self.allocate_batch(
            TaskBatch.from_tasks([task], now), residual_cpu, residual_mem,
            window, now,
            cap_cpu=snapshot.allocatable_cpu, cap_mem=snapshot.allocatable_mem,
        )
        return allocation_at(result, 0)


@dataclasses.dataclass
class FCFSAllocator:
    """Baseline (§6.1.6): first-come-first-serve full-request allocation.

    No lifecycle look-ahead, no scaling: the task gets exactly its declared
    request when some node has room, else it waits for other pods to
    release resources.
    """

    placement: str = "worst_fit"
    backend: str = "auto"
    layout: FederatedLayout | None = None
    cluster_sharding: str = "auto"

    name: str = "fcfs"
    mode = "fcfs"

    def _mesh(self):
        return federation.resolve_mesh(self.layout, self.cluster_sharding)

    def allocate_batch(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
        cap_cpu=None,
        cap_mem=None,
    ) -> BatchAllocation:
        return _dispatch_burst(
            batch, residual_cpu, residual_mem, window, now,
            alpha=0.0, beta=0.0, policy=self.placement, mode=self.mode,
            backend=self.backend, cap_cpu=cap_cpu, cap_mem=cap_mem,
            layout=self.layout, mesh=self._mesh(),
        )

    def create_state(self, residual_cpu, residual_mem, cap_cpu, cap_mem
                     ) -> DeviceResidualState:
        """See ``AdaptiveAllocator.create_state``."""
        return DeviceResidualState.create(
            residual_cpu, residual_mem, cap_cpu, cap_mem,
            self.layout, RES_PAD,
        )

    def allocate_batch_async(
        self,
        batch: TaskBatch,
        window: TaskWindow,
        now: float,
        *,
        state: DeviceResidualState,
        updates=None,
    ) -> PendingBurst:
        """See ``AdaptiveAllocator.allocate_batch_async``."""
        return _issue_state_burst(
            batch, state, window, now,
            alpha=0.0, beta=0.0, policy=self.placement, mode=self.mode,
            backend=self.backend, updates=updates,
        )

    def begin_replay(
        self,
        batch: TaskBatch,
        residual_cpu,
        residual_mem,
        window: TaskWindow,
        now: float,
        cap_cpu=None,
        cap_mem=None,
    ) -> BurstReplay:
        return BurstReplay(
            batch, residual_cpu, residual_mem, window, now, cap_cpu, cap_mem,
            alpha=0.0, beta=0.0, policy=self.placement, mode=self.mode,
            layout=self.layout,
        )

    def allocate(
        self,
        task: TaskSpec,
        snapshot: ClusterSnapshot,
        window: TaskWindow,
        now: float,
    ) -> Allocation:
        residual_cpu, residual_mem = discovery.discover(snapshot)
        result = self.allocate_batch(
            TaskBatch.from_tasks([task], now), residual_cpu, residual_mem,
            window, now,
            cap_cpu=snapshot.allocatable_cpu, cap_mem=snapshot.allocatable_mem,
        )
        return allocation_at(result, 0)


# Registry entries (repro.api.registry.ALLOCATORS): the engine selects
# allocators by name and consults capability flags instead of
# string-matching — ``adaptive_scaling`` tells it to hand over the ARAS
# alpha/beta knobs; third-party allocators register the same way.

@ALLOCATORS.register(
    "aras",
    capabilities=("adaptive_scaling", "federation_aware",
                  "lifecycle_window", "device_state"),
    doc="ARAS (Alg. 1): lifecycle-window demand + Alg. 3 adaptive "
        "scaling")
def _build_aras(**kwargs) -> AdaptiveAllocator:
    return AdaptiveAllocator(**kwargs)


@ALLOCATORS.register(
    "adaptive_scaling",
    capabilities=("adaptive_scaling", "federation_aware",
                  "lifecycle_window", "device_state", "forecast"),
    doc="predictive ARAS: Alg. 3 priced against forecast-horizon demand "
        "(repro.forecast ghost record)")
def _build_adaptive_scaling(**kwargs) -> AdaptiveAllocator:
    """ARAS arithmetic, predictive demand window.

    The allocator itself is the unmodified :class:`AdaptiveAllocator`
    (mode ``"aras"`` — same fused kernel, bit-identical sequential
    core).  The ``forecast`` capability is what changes behaviour: the
    engine appends a *ghost record* to the knowledge-base window of
    every burst decision, carrying the expected resource demand of the
    forecast horizon (``repro.forecast.ArrivalForecaster.
    horizon_demand``).  Alg. 1's request accumulation then prices load
    that has not arrived yet, so Alg. 3's proportional cuts tighten
    quotas *ahead* of a predicted burst — pre-provisioning — instead of
    waiting for the burst to saturate the cluster.  Requires
    ``ForecastConfig.enabled`` (EngineConfig.validate enforces it).
    """
    return AdaptiveAllocator(name="adaptive_scaling", **kwargs)


@ALLOCATORS.register(
    "fcfs",
    aliases=("baseline",),
    capabilities=("federation_aware", "device_state"),
    doc="§6.1.6 baseline: first-come-first-serve full-request allocation")
def _build_fcfs(**kwargs) -> FCFSAllocator:
    # FCFS has no scaling knobs: accept-and-drop alpha/beta so callers
    # can hand every allocator the same kwargs.
    return FCFSAllocator(
        **{k: v for k, v in kwargs.items()
           if k in ("placement", "backend", "layout", "cluster_sharding")}
    )


def make_allocator(name: str, **kwargs) -> AdaptiveAllocator | FCFSAllocator:
    """Build a registered allocator by name (see ``ALLOCATORS``)."""
    return ALLOCATORS.get(name).factory(**kwargs)
