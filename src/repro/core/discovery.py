"""Resource discovery — Algorithm 2 of the paper, vectorized.

The paper's Go implementation loops ``for node × for pod`` (O(m·p)) against
Informer caches.  Here the same computation is a single
``jax.ops.segment_sum`` over the pod table — one fused pass that scales to
100k-node clusters (see ``benchmarks/allocator_scale.py``), which is the
1000+-node answer the control plane needs.

Outputs match Alg. 2 exactly: per-node residual = allocatable − Σ(requests
of Running/Pending pods hosted on the node).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ClusterSnapshot


@functools.partial(jax.jit, static_argnames=("num_nodes",))
def _residuals(
    allocatable_cpu: jax.Array,
    allocatable_mem: jax.Array,
    pod_node: jax.Array,
    pod_cpu: jax.Array,
    pod_mem: jax.Array,
    pod_active: jax.Array,
    *,
    num_nodes: int,
) -> Tuple[jax.Array, jax.Array]:
    """Per-node residual (cpu, mem). Alg. 2 lines 4-23."""
    active = pod_active.astype(pod_cpu.dtype)
    # Alg.2 lines 6-13: accumulate requests of Running/Pending pods per node.
    node_req_cpu = jax.ops.segment_sum(
        pod_cpu * active, pod_node, num_segments=num_nodes
    )
    node_req_mem = jax.ops.segment_sum(
        pod_mem * active, pod_node, num_segments=num_nodes
    )
    # Alg.2 lines 15-20: residual = allocatable − occupied.
    return allocatable_cpu - node_req_cpu, allocatable_mem - node_req_mem


# Public name for array-level callers (e.g. benchmarks) that hold raw
# node/pod arrays rather than a ClusterSnapshot.
node_residuals = _residuals


def discover(snapshot: ClusterSnapshot) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ResidualMap equivalent: arrays of per-node residual CPU / memory."""
    return _residuals(
        jnp.asarray(snapshot.allocatable_cpu, jnp.float32),
        jnp.asarray(snapshot.allocatable_mem, jnp.float32),
        jnp.asarray(snapshot.pod_node, jnp.int32),
        jnp.asarray(snapshot.pod_cpu, jnp.float32),
        jnp.asarray(snapshot.pod_mem, jnp.float32),
        jnp.asarray(snapshot.pod_active),
        num_nodes=snapshot.num_nodes,
    )


@jax.jit
def summarize(residual_cpu: jax.Array, residual_mem: jax.Array):
    """Alg. 1 lines 16-23: totals plus the max-residual node.

    The paper assumes the node with maximal remaining CPU also holds the
    maximal remaining memory ("prioritize CPU resource for allocation",
    §5.1) — Re_max^{mem} is read off the argmax-CPU node, matching Alg. 1
    lines 19-22 where both maxima update together.
    """
    total_cpu = jnp.sum(residual_cpu)
    total_mem = jnp.sum(residual_mem)
    idx = jnp.argmax(residual_cpu)
    return {
        "total_cpu": total_cpu,
        "total_mem": total_mem,
        "max_node": idx,
        "re_max_cpu": residual_cpu[idx],
        "re_max_mem": residual_mem[idx],
    }
