# ARAS — the paper's primary contribution (Algorithms 1-3 + MAPE-K),
# implemented as vectorized JAX with a thin object front-end.
from repro.core.allocator import AdaptiveAllocator, FCFSAllocator, make_allocator
from repro.core.evaluation import EvalInputs, EvalResult, evaluate, evaluate_batch
from repro.core.mapek import MapeK
from repro.core.types import (
    DEFAULT_ALPHA,
    DEFAULT_BETA,
    Allocation,
    ClusterSnapshot,
    PodPhase,
    Resources,
    TaskSpec,
    TaskWindow,
)

__all__ = [
    "AdaptiveAllocator",
    "FCFSAllocator",
    "make_allocator",
    "EvalInputs",
    "EvalResult",
    "evaluate",
    "evaluate_batch",
    "MapeK",
    "Allocation",
    "ClusterSnapshot",
    "PodPhase",
    "Resources",
    "TaskSpec",
    "TaskWindow",
    "DEFAULT_ALPHA",
    "DEFAULT_BETA",
]
