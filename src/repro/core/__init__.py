# ARAS — the paper's primary contribution (Algorithms 1-3 + MAPE-K),
# implemented as vectorized JAX with a thin object front-end.
from repro.core.allocator import AdaptiveAllocator, FCFSAllocator, make_allocator
from repro.core.discovery import discover, node_residuals
from repro.core.evaluation import EvalInputs, EvalResult, evaluate, evaluate_batch
from repro.core.mapek import MapeK
from repro.core.placement import PLACEMENT_POLICIES, pick_node
from repro.core.types import (
    DEFAULT_ALPHA,
    DEFAULT_BETA,
    Allocation,
    BatchAllocation,
    ClusterSnapshot,
    PodPhase,
    Resources,
    TaskBatch,
    TaskSpec,
    TaskWindow,
)

__all__ = [
    "AdaptiveAllocator",
    "FCFSAllocator",
    "make_allocator",
    "discover",
    "node_residuals",
    "EvalInputs",
    "EvalResult",
    "evaluate",
    "evaluate_batch",
    "MapeK",
    "PLACEMENT_POLICIES",
    "pick_node",
    "Allocation",
    "BatchAllocation",
    "ClusterSnapshot",
    "PodPhase",
    "Resources",
    "TaskBatch",
    "TaskSpec",
    "TaskWindow",
    "DEFAULT_ALPHA",
    "DEFAULT_BETA",
]
