"""Resource evaluation — Algorithm 3 + Eq. (9), as a branchless lattice.

The paper's 60-line nested conditional reduces to a closed form over the
six conditions (proof: enumerate the 4 scenarios × 4 sub-cases — covered
exhaustively in ``tests/test_evaluation.py``):

    A1 = request.cpu  < totalResidual.cpu     (cluster CPU sufficient)
    A2 = request.mem  < totalResidual.mem     (cluster memory sufficient)
    B1 = task.cpu     < Re_max_cpu            (request fits max-residual node)
    B2 = task.mem     < Re_max_mem
    C1 = cpu_cut      < Re_max_cpu            (scaled cut fits that node)
    C2 = mem_cut      < Re_max_mem

    cpu = A1 ? (B1 ? task.cpu : Re_max_cpu·α) : (A2 ? (C1 ? cpu_cut : Re_max_cpu·α) : cpu_cut)
    mem = A2 ? (B2 ? task.mem : Re_max_mem·α) : (A1 ? (C2 ? mem_cut : Re_max_mem·α) : mem_cut)

with the resource-scaling rule (Eq. 9)

    cpu_cut = task.cpu · totalResidual.cpu / request.cpu
    mem_cut = task.mem · totalResidual.mem / request.mem

Being branchless, the evaluator vmaps over whole batches of pending task
requests — the engine amortizes one device dispatch across every request
in an arrival burst.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import DEFAULT_ALPHA


class EvalInputs(NamedTuple):
    """Scalar (or batched) inputs of Algorithm 3."""

    task_cpu: jax.Array
    task_mem: jax.Array
    request_cpu: jax.Array  # in-window accumulated demand (Alg. 1)
    request_mem: jax.Array
    total_residual_cpu: jax.Array  # cluster-wide residual (Alg. 2)
    total_residual_mem: jax.Array
    re_max_cpu: jax.Array  # residual on the max-residual node
    re_max_mem: jax.Array


class EvalResult(NamedTuple):
    cpu: jax.Array
    mem: jax.Array
    scenario: jax.Array  # int32 ∈ {0,1,2,3}: (¬A1)·1 + (¬A2)·2


def evaluate(inputs: EvalInputs, alpha: float = DEFAULT_ALPHA) -> EvalResult:
    """Branchless Algorithm 3. Safe under vmap/jit; no python control flow."""
    t_cpu, t_mem = inputs.task_cpu, inputs.task_mem
    req_cpu = jnp.maximum(inputs.request_cpu, 1e-9)  # Eq. 9 denominators
    req_mem = jnp.maximum(inputs.request_mem, 1e-9)
    tot_cpu, tot_mem = inputs.total_residual_cpu, inputs.total_residual_mem
    remax_cpu, remax_mem = inputs.re_max_cpu, inputs.re_max_mem

    # Eq. (9): scale the declared request by residual/demand.
    cpu_cut = t_cpu * tot_cpu / req_cpu
    mem_cut = t_mem * tot_mem / req_mem

    a1 = req_cpu < tot_cpu
    a2 = req_mem < tot_mem
    b1 = t_cpu < remax_cpu
    b2 = t_mem < remax_mem
    c1 = cpu_cut < remax_cpu
    c2 = mem_cut < remax_mem

    cpu = jnp.where(
        a1,
        jnp.where(b1, t_cpu, remax_cpu * alpha),
        jnp.where(a2, jnp.where(c1, cpu_cut, remax_cpu * alpha), cpu_cut),
    )
    mem = jnp.where(
        a2,
        jnp.where(b2, t_mem, remax_mem * alpha),
        jnp.where(a1, jnp.where(c2, mem_cut, remax_mem * alpha), mem_cut),
    )
    scenario = (~a1).astype(jnp.int32) + 2 * (~a2).astype(jnp.int32)
    return EvalResult(cpu=cpu, mem=mem, scenario=scenario)


evaluate_jit = jax.jit(evaluate, static_argnames=("alpha",))

# Batched form: one dispatch for a whole burst of task requests.  Cluster
# summary terms broadcast; per-task terms are batched on the leading axis.
evaluate_batch = jax.jit(
    jax.vmap(
        evaluate,
        in_axes=(EvalInputs(0, 0, 0, 0, None, None, None, None), None),
    ),
    static_argnames=("alpha",),
)

# Sentinel scenario code emitted by the fused kernel in FCFS mode, where
# Alg. 3 never runs (the baseline always grants the full request).
FCFS_SCENARIO = -1

SCENARIO_NAMES = {
    0: "sufficient",  # A1 ∧ A2   (paper case 1)
    1: "cpu_insufficient",  # ¬A1 ∧ A2  (case 2)
    2: "mem_insufficient",  # A1 ∧ ¬A2  (case 3)
    3: "both_insufficient",  # ¬A1 ∧ ¬A2 (case 4)
    FCFS_SCENARIO: "fcfs",
}
