from repro.engine.kubeadaptor import (
    AllocatorConfig,
    ClusterConfig,
    EngineConfig,
    EngineMetrics,
    FaultConfig,
    KubeAdaptor,
    TimingConfig,
    run_experiment,
)
from repro.engine.state_store import StateStore, TaskRecord

__all__ = [
    "AllocatorConfig",
    "ClusterConfig",
    "EngineConfig",
    "EngineMetrics",
    "FaultConfig",
    "KubeAdaptor",
    "TimingConfig",
    "run_experiment",
    "StateStore",
    "TaskRecord",
]
