from repro.engine.kubeadaptor import (
    EngineConfig,
    EngineMetrics,
    KubeAdaptor,
    run_experiment,
)
from repro.engine.state_store import StateStore, TaskRecord

__all__ = [
    "EngineConfig",
    "EngineMetrics",
    "KubeAdaptor",
    "run_experiment",
    "StateStore",
    "TaskRecord",
]
