"""KubeAdaptor — the workflow engine (paper Fig. 2), discrete-event form.

Components map 1:1 to the paper:

* Workflow Injection Module  → ``inject`` events from an arrival pattern
* Interface Unit             → ready-task decomposition + state tracking
* Resource Manager           → pluggable allocator (ARAS / FCFS baseline)
  driven through the MAPE-K cycle
* Containerized Executor     → ``ClusterSim.bind`` (pod creation)
* Task Container Cleaner     → delayed pod deletion, OOMKilled watch
* Redis                      → ``StateStore``

Fault-tolerance semantics follow §6.2.2: a pod whose memory quota is below
its *runtime* requirement + β turns OOMKilled mid-run; the engine deletes
it, re-allocates with the learned floor, and relaunches (self-healing).

Vertical adaptivity (``EngineConfig.vertical`` / ``repro.vertical``,
ARC-V) layers an in-place resize controller on top: while usage-curve
pods run, a periodic ``RESIZE`` sweep shrinks over-provisioned quotas
back into the cluster books (the freed capacity is offered to the
pending queue by a same-time retry) and grows under-provisioned ones
headroom-permitting, and the §6.2.2 kill becomes a *resize-first*
policy — an OOM-bound pod on a node with memory headroom is grown to
its runtime floor in place and runs to its original completion.

Injected chaos (``EngineConfig.faults``, schedules from ``repro.chaos``)
extends that story beyond OOM: ``NODE_DOWN`` cordons a node (its running
pods terminate ``FAILED`` and re-enter admission through the same HEAL
path), ``NODE_UP`` restores it (scheduling a retry against the recovered
capacity), and ``OOM_STORM`` force-OOMs the longest-running pods.  Pod
events can therefore go *stale* — a queued COMPLETE/OOM whose pod was
already killed by chaos or a workflow failure — so the handlers guard on
the pod still being Running.  Degradation is bounded: an optional retry
budget (``max_retries``) turns the next admission failure into a FAILED
workflow outcome, exponential backoff (``backoff_base``/``factor``)
gates the retry queue between failed rounds, and ``workflow_timeout``
deadlines terminate stuck workflows — all surfaced on
:class:`EngineMetrics` (displaced/recovered/failed counters and
time-to-recovery).

The allocation unit is the **arrival burst**: retry/ready/heal events
within ``TimingConfig.batch_window`` seconds of the head event drain into
a single ``allocate_batch`` dispatch (one fused MAPE-K cycle for the
whole burst) instead of one cycle per task — the event machinery lives
in ``repro.engine.events`` (typed :class:`EventKind` taxonomy +
:class:`EventQueue` with the windowed-drain primitive).  The default
``batch_window=0.0`` folds only same-timestamp events, bit-for-bit the
seed's lockstep drain; a positive window additionally folds jittered
near-simultaneous arrivals from stochastic injectors ("decide at t+ε"),
with the decision made at the last folded event's timestamp.  The
batched retry preserves the seed's FIFO admission order *and* its
head-of-line discipline (§6.1.6: the engine "waits ... for the CURRENT
task request"): pending rows go first, and once one fails the rest of the
queue is skipped, exactly as the sequential loop would.

Multi-cluster mode (``num_clusters > 1``) federates the node table into
contiguous cluster shards (``repro.cluster.federation``): bursts dispatch
through the sharded residual carry (per-shard totals, cluster-major
tiles, optional ``clusters`` device mesh) while the event loop, retry
queue and self-healing stay unchanged — node ids in every result are
global, so binding is cluster-agnostic.

Per-task mode (``batch_allocation=False``) drains the same burst but
*replays* it one dispatch per row — each decision syncs back to the host,
binds, and the next row's residual carry is rebuilt from the engine's
incremental float32 caches (``repro.core.allocator.BurstReplay``).  Both
modes execute the same step arithmetic against the same caches, so
decisions are bit-for-bit identical — see ``tests/test_batch_parity.py``
— while the replay independently verifies that the fused core's in-scan
debits and record stamps track the engine's host-side state transitions.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.api.config import (
    AllocatorConfig,
    ClusterConfig,
    EngineConfig,
    FaultConfig,
    TimingConfig,
    VerticalConfig,
)
from repro.api.registry import ALLOCATORS
from repro.cluster import federation
from repro.cluster.simulator import ClusterSim
from repro.core.allocator import allocation_at
from repro.core.types import (
    Allocation,
    BatchAllocation,
    PodPhase,
    TaskBatch,
    TaskSpec,
    TaskWindow,
)
from repro.engine.events import ALLOCATABLE, Event, EventKind, EventQueue
from repro.engine.state_store import StateStore, TaskRecord
from repro.workflows.spec import WorkflowSpec

# The engine configuration is the composed, typed form from the
# Scenario API (repro.api.config): frozen ClusterConfig /
# AllocatorConfig / TimingConfig composed into EngineConfig (flat
# constructor kwargs completed their deprecation cycle and are gone).
# Re-exported here so `from repro.engine import EngineConfig` keeps
# working across the redesign.
__all__ = [
    "AllocatorConfig", "ClusterConfig", "EngineConfig", "EngineMetrics",
    "FaultConfig", "KubeAdaptor", "TimingConfig", "VerticalConfig",
    "WorkflowRun", "run_experiment",
]


@dataclasses.dataclass
class WorkflowRun:
    spec: WorkflowSpec
    injected_at: float
    indegree: Dict[str, int] = dataclasses.field(default_factory=dict)
    done: set = dataclasses.field(default_factory=set)
    first_start: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def complete(self) -> bool:
        return len(self.done) == self.spec.num_tasks


@dataclasses.dataclass
class EngineMetrics:
    """Evaluation metrics of §6.1.5 + trace series for Figs. 5-9."""

    makespan: float = 0.0  # Total Duration of All Workflows
    workflow_durations: Dict[str, float] = dataclasses.field(default_factory=dict)
    # time-weighted average utilization (quota / allocatable)
    avg_cpu_usage: float = 0.0
    avg_mem_usage: float = 0.0
    usage_series: List[Tuple[float, float, float]] = dataclasses.field(
        default_factory=list
    )
    oom_events: List[Tuple[float, str]] = dataclasses.field(default_factory=list)
    realloc_events: List[Tuple[float, str]] = dataclasses.field(default_factory=list)
    alloc_trace: List[Tuple[float, str, float, float, str]] = dataclasses.field(
        default_factory=list
    )
    num_allocations: int = 0
    num_waits: int = 0
    # Dispatch efficiency of the windowed drain: how many device
    # dispatches the allocation path issued (batched mode: one per
    # drained burst; per-task replay: one per row) and how many task
    # rows they carried in total.
    num_dispatches: int = 0
    dispatched_rows: int = 0
    # SLA accounting (paper Eqs. 2-4): per-workflow deadline violations
    sla_violations: List[Tuple[str, float, float]] = dataclasses.field(
        default_factory=list  # (workflow, finished_at, deadline)
    )
    # Fault-injection + graceful-degradation accounting (repro.chaos):
    node_events: List[Tuple[float, int, str]] = dataclasses.field(
        default_factory=list  # (t, node, "down"|"up")
    )
    displaced_tasks: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list  # (t, wf/task) running pods lost to NODE_DOWN
    )
    recovery_times: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list  # (wf/task, displaced -> re-bound seconds)
    )
    failed_tasks: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list  # (t, wf/task) retry budget exhausted
    )
    failed_workflows: List[Tuple[float, str, str]] = dataclasses.field(
        default_factory=list  # (t, workflow, "retry_budget"|"deadline")
    )
    # Forecast telemetry (EngineConfig.forecast / repro.forecast):
    # arrivals observed, drains whose fold window came from a live
    # prediction (+ the summed window for the mean), and burst decisions
    # that priced a ghost forecast-demand record (adaptive_scaling).
    forecast_observations: int = 0
    forecast_predictions: int = 0
    forecast_window_sum: float = 0.0
    forecast_ghost_rows: int = 0
    # Vertical adaptivity telemetry (EngineConfig.vertical /
    # repro.vertical): in-place resizes of running pods, the capacity a
    # shrink returned to the books integrated over the pod's remaining
    # lifetime (millicore·s / MiB·s), and OOM kills the resize-first
    # policy converted into in-place grows.
    num_resizes: int = 0
    num_shrinks: int = 0
    num_grows: int = 0
    resizes_avoided_oom: int = 0
    reclaimed_cpu_seconds: float = 0.0
    reclaimed_mem_seconds: float = 0.0
    resize_events: List[Tuple[float, str, float, float]] = dataclasses.field(
        default_factory=list  # (t, wf/task, Δcpu, Δmem) signed quota deltas
    )

    @property
    def mean_forecast_window(self) -> float:
        """Mean adaptive fold window across predicted drains, seconds."""
        return (self.forecast_window_sum / self.forecast_predictions
                if self.forecast_predictions else 0.0)

    @property
    def sla_violation_rate(self) -> float:
        n = len(self.workflow_durations)
        return len(self.sla_violations) / n if n else 0.0

    @property
    def num_displaced(self) -> int:
        return len(self.displaced_tasks)

    @property
    def num_recovered(self) -> int:
        """Displaced tasks that re-entered admission and re-bound."""
        return len(self.recovery_times)

    @property
    def mean_time_to_recovery(self) -> float:
        """Mean seconds from displacement to the recovering bind."""
        return (float(np.mean([dt for _, dt in self.recovery_times]))
                if self.recovery_times else 0.0)

    @property
    def avg_workflow_duration(self) -> float:
        vals = list(self.workflow_durations.values())
        return float(np.mean(vals)) if vals else 0.0

    @property
    def mean_burst_width(self) -> float:
        """Mean task rows per allocation dispatch (1.0 in replay mode)."""
        return (self.dispatched_rows / self.num_dispatches
                if self.num_dispatches else 0.0)


class KubeAdaptor:
    """Discrete-event engine executing workflows under an allocator."""

    def __init__(self, config: EngineConfig):
        # Fail at construction, not first dispatch, on a typo'd name or
        # an impossible federation split (actionable messages).
        config.validate()
        self.cfg = config
        cluster_cfg, alloc_cfg = config.cluster, config.alloc
        self.cluster = ClusterSim(cluster_cfg.num_nodes,
                                  cluster_cfg.node_cpu,
                                  cluster_cfg.node_mem,
                                  num_clusters=cluster_cfg.num_clusters)
        # Burst dispatches go through the federated layout whenever there
        # is more than one cluster; "force" also routes the single-cluster
        # setup through the K=1 federated path (bit-for-bit the legacy
        # allocator — the cross-shard parity suite holds it to that).
        layout = (federation.layout_of(self.cluster)
                  if cluster_cfg.num_clusters > 1
                  or cluster_cfg.sharding == "force" else None)
        entry = ALLOCATORS.get(alloc_cfg.algorithm)
        kwargs = {"placement": alloc_cfg.placement,
                  "backend": alloc_cfg.backend,
                  "layout": layout,
                  "cluster_sharding": cluster_cfg.sharding}
        if entry.supports("adaptive_scaling"):
            kwargs.update(alpha=alloc_cfg.alpha, beta=alloc_cfg.beta)
        self.allocator = entry.factory(**kwargs)
        # Device-resident incremental dispatch: fused bursts decide
        # against tiles that persist on device, maintained by dirty-node
        # scatter updates instead of per-burst O(nodes) re-pads.  Gated
        # on the allocator capability, batched mode (the replay path is
        # *defined* as rebuilding the carry from host caches), the
        # config knob, and the absence of a device mesh (the sharded
        # layout re-places tiles per dispatch).
        self._use_device_state = (
            alloc_cfg.batch_allocation
            and alloc_cfg.incremental_state
            and entry.supports("device_state")
            and self.allocator._mesh() is None
        )
        self._state = None  # DeviceResidualState, created on first burst
        # Online arrival forecasting (EngineConfig.forecast).  The
        # forecaster observes every injection; the drain sizes its fold
        # window from the predicted gap, and forecast-capable allocators
        # (``adaptive_scaling``) additionally price a ghost record
        # carrying the forecast-horizon demand.  Disabled (default) the
        # attribute stays None and every consumer takes the static path
        # — bit-for-bit today's engine.
        self._predictive = entry.supports("forecast")
        if config.forecast.enabled:
            from repro.forecast import ArrivalForecaster

            self._forecaster: Optional[ArrivalForecaster] = \
                ArrivalForecaster(config.forecast)
        else:
            self._forecaster = None
        # Streaming overlap hook: called between issuing a fused dispatch
        # and syncing its results, while the device is busy
        # (repro.serving.stream sets it to pump arrival ingestion).
        self.ingest_hook: Optional[Callable[[], None]] = None
        self.store = StateStore()
        self.runs: Dict[str, WorkflowRun] = {}
        self.metrics = EngineMetrics()
        self.queue = EventQueue()
        self._pending: Deque[Tuple[str, TaskSpec]] = deque()
        self._now = 0.0
        self._t_first: Optional[float] = None
        self._last_sample = (0.0, 0.0, 0.0)  # (t, cpu_util, mem_util)
        self._util_integral = np.zeros(2)
        # Fault injection + graceful degradation (repro.chaos).  The
        # bookkeeping dicts stay empty without a FaultConfig, so the hot
        # path pays one falsy check per bind at most.
        faults = config.faults
        self._fault_cfg = faults
        self._attempts: Dict[str, int] = {}  # wf/task -> failed admissions
        self._displaced_at: Dict[str, float] = {}  # wf/task -> t displaced
        self._failed_workflows: set = set()
        self._retry_gate = 0.0  # retries before this time stay gated
        self._backoff_round = 0
        # Stale-event dropping (see _event_stale) only matters once
        # something can kill pods or fail workflows; keep it off the
        # no-fault hot path.
        self._chaos_on = (faults.schedule != "none"
                          or faults.max_retries is not None
                          or faults.workflow_timeout is not None
                          or faults.backoff_base > 0)
        # Vertical adaptivity (EngineConfig.vertical / repro.vertical):
        # the resize controller arms a periodic RESIZE event while a
        # usage-curve pod is running.  Disabled (default) no RESIZE event
        # is ever queued — bit-for-bit today's engine.
        self._vertical = config.vertical.enabled
        self._resize_armed = False
        if faults.schedule != "none":
            from repro.api.registry import FAULTS

            entry = FAULTS.get(faults.schedule)
            schedule = entry.factory(
                num_nodes=cluster_cfg.num_nodes,
                **{"seed": faults.seed, **dict(faults.params)})
            for fault in schedule:
                self._push(fault.t, fault.kind, fault.payload)

    # ----------------------------------------------------------- plumbing
    def _push(self, t: float, kind: EventKind, payload: tuple) -> None:
        self.queue.push(t, kind, payload)

    def submit(self, spec: WorkflowSpec, at: float) -> None:
        self._push(at, EventKind.INJECT, (spec,))

    def _sample_usage(self) -> None:
        """Advance the time-weighted utilization integral to ``now``."""
        t0, cu, mu = self._last_sample
        dt = self._now - t0
        if dt > 0:
            self._util_integral += dt * np.array([cu, mu])
        u = self.cluster.utilization()
        self._last_sample = (self._now, u.cpu, u.mem)
        self.metrics.usage_series.append((self._now, u.cpu, u.mem))

    # -------------------------------------------------------------- phases
    def _inject(self, spec: WorkflowSpec) -> None:
        """Workflow Injection Module + Interface Unit decomposition."""
        if self._forecaster is not None:
            # One observation per arrival: timestamp + total declared
            # demand (the horizon-demand intensity estimate).
            self._forecaster.observe(
                self._now,
                cpu=sum(t.cpu for t in spec.tasks.values()),
                mem=sum(t.mem for t in spec.tasks.values()),
            )
            self.metrics.forecast_observations += 1
        run = WorkflowRun(spec=spec, injected_at=self._now,
                          indegree=spec.indegrees())
        self.runs[spec.workflow_id] = run
        # Plan-phase knowledge: projected earliest starts for every task.
        est = spec.earliest_starts(self._now)
        for tid, task in spec.tasks.items():
            self.store.put(TaskRecord(
                key=f"{spec.workflow_id}/{tid}", t_start=est[tid],
                duration=task.duration, cpu=task.cpu, mem=task.mem,
            ))
        for tid in spec.roots():
            self._push(self._now, EventKind.READY, (spec.workflow_id, tid))
        if self._fault_cfg.workflow_timeout is not None:
            self._push(self._now + self._fault_cfg.workflow_timeout,
                       EventKind.WF_DEADLINE, (spec.workflow_id,))

    # --------------------------------------------------- burst allocation
    def _batch_of(self, entries: List[Tuple[str, TaskSpec, str]]
                  ) -> TaskBatch:
        return TaskBatch.from_tasks(
            [task for _, task, _ in entries],
            self._now,
            self_slots=[
                self.store.index_of(f"{wf_id}/{task.task_id}")
                for wf_id, task, _ in entries
            ],
            pending=[origin == "pending" for _, _, origin in entries],
        )

    def fold_window(self) -> float:
        """Seconds of fold entitlement for the next drained burst.

        The static ``TimingConfig.batch_window`` unless forecasting is
        enabled *and* the forecaster has enough history, in which case
        the window is sized from the predicted next inter-arrival gap
        (``repro.forecast.ArrivalForecaster.fold_window``).  Public
        because the serving pump (``repro.serving.stream``) must grant
        the engine exactly this entitlement when deciding which
        arrivals the next step may see.
        """
        if self._forecaster is None:
            return self.cfg.timing.batch_window
        return self._forecaster.fold_window(self.cfg.timing.batch_window)

    def _alloc_window(self) -> TaskWindow:
        """The knowledge-base window a burst decision prices against.

        For forecast-capable allocators (``adaptive_scaling``) with a
        live prediction, one *ghost record* is appended: stamped at
        ``now``, never done, carrying the expected resource demand of
        the forecast horizon.  Alg. 1's request accumulation then sees
        load that has not arrived yet and Alg. 3's proportional cuts
        tighten quotas ahead of the predicted burst — predictive
        pre-provisioning with zero kernel changes.  The ghost lives
        only in this per-decision view; the store itself is untouched.
        """
        window = self.store.window()
        if not self._predictive or self._forecaster is None:
            return window
        # Present demand outranks predicted demand: while tasks already
        # sit in the retry queue the cluster is refusing real admissions,
        # and a ghost on top would tighten quotas further — each
        # no-progress round arms the exponential backoff gate, so the
        # compounding idle time dwarfs any pre-provisioning benefit.
        if self._pending:
            return window
        ghost_cpu, ghost_mem = self._forecaster.horizon_demand()
        # Bound the ghost to a fraction of what the cluster could even
        # give: pre-provisioning shares capacity with predicted load,
        # it must never starve present admissions below their floors.
        res_cpu, res_mem = self.cluster.residual_view()
        cap = self.cfg.forecast.ghost_cap
        ghost_cpu = min(ghost_cpu, cap * float(np.sum(res_cpu)))
        ghost_mem = min(ghost_mem, cap * float(np.sum(res_mem)))
        if ghost_cpu <= 0.0 and ghost_mem <= 0.0:
            return window
        self.metrics.forecast_ghost_rows += 1
        # Appending keeps every existing slot index valid (self-exclusion
        # masks point at unchanged positions); the store's free tail
        # slots are done=True and numerically inert either way.
        return TaskWindow(
            t_start=np.append(window.t_start, np.float32(self._now)),
            cpu=np.append(window.cpu, np.float32(ghost_cpu)),
            mem=np.append(window.mem, np.float32(ghost_mem)),
            done=np.append(window.done, False),
        )

    def _flush_state(self):
        """The device state plus the dirty set pending against it.

        First call stages the whole cluster once and turns on the
        simulator's dirty-node journal (no updates pending); afterwards
        it drains the nodes touched since the previous burst, with
        values read from the same authoritative float32 caches
        ``residual_view`` exposes.  The allocator folds the returned
        dirty set into the decision dispatch itself (one fused
        maintain-and-decide call), so the tiles always equal what the
        re-pad path would rebuild.
        """
        if self._state is None:
            res_cpu, res_mem = self.cluster.residual_view()
            cap_cpu, cap_mem = self.cluster.capacity_view()
            self._state = self.allocator.create_state(
                res_cpu, res_mem, cap_cpu, cap_mem)
            self.cluster.track_dirty()
            return self._state, None
        return self._state, self.cluster.drain_dirty()

    def _decide(self, entries: List[Tuple[str, TaskSpec, str]]
                ) -> BatchAllocation:
        """One fused MAPE-K cycle for a burst of task requests.

        Monitor reads the incremental caches (no snapshot rebuild);
        Analyse/Plan run inside the allocator's single dispatch; Execute
        happens in ``_allocate_group``/``_bind`` from the one synced
        result.

        On the device-state path the dispatch is issued asynchronously
        against the incrementally-maintained tiles; while the device
        computes, the streaming ingest hook (if any) runs — the
        double-buffered overlap — and only then does the engine block on
        the results.
        """
        if self._use_device_state:
            state, updates = self._flush_state()
            pending = self.allocator.allocate_batch_async(
                self._batch_of(entries), self._alloc_window(), self._now,
                state=state, updates=updates,
            )
            self._state = pending.state
            if self.ingest_hook is not None:
                self.ingest_hook()
            return pending.wait()
        res_cpu, res_mem = self.cluster.residual_view()
        cap_cpu, cap_mem = self.cluster.capacity_view()
        return self.allocator.allocate_batch(
            self._batch_of(entries), res_cpu, res_mem,
            self._alloc_window(), self._now,
            cap_cpu=cap_cpu, cap_mem=cap_mem,
        )

    def _decision_rows(self, entries: List[Tuple[str, TaskSpec, str]]):
        """Yield (feasible, attempted, Allocation) per entry, in order.

        Batched mode decides everything in one fused dispatch up front;
        per-task mode replays the same burst one dispatch per row, reading
        the engine's live residual caches *after* each preceding bind (the
        generator suspends at ``yield`` while the consumer applies the
        decision) — the sequential MAPE-K reference.
        """
        if self.cfg.alloc.batch_allocation:
            result = self._decide(entries)
            for i in range(len(entries)):
                yield (bool(result.feasible[i]), bool(result.attempted[i]),
                       allocation_at(result, i))
        else:
            res_cpu, res_mem = self.cluster.residual_view()
            cap_cpu, cap_mem = self.cluster.capacity_view()
            replay = self.allocator.begin_replay(
                self._batch_of(entries), res_cpu, res_mem,
                self._alloc_window(), self._now,
                cap_cpu=cap_cpu, cap_mem=cap_mem,
            )
            for i in range(len(entries)):
                res_cpu, res_mem = self.cluster.residual_view()
                alloc, attempted = replay.step(i, res_cpu, res_mem)
                yield alloc.feasible, attempted, alloc

    def _bind(self, wf_id: str, task: TaskSpec, alloc: Allocation) -> None:
        """Execute phase: Containerized Executor creates the pod."""
        key = f"{wf_id}/{task.task_id}"
        pod = self.cluster.bind(task, alloc, self._now, workflow_id=wf_id)
        self.store.mark_started(key, self._now)
        if self._displaced_at:
            t0 = self._displaced_at.pop(key, None)
            if t0 is not None:  # a displaced task recovered (re-bound)
                self.metrics.recovery_times.append((key, self._now - t0))
        if self._attempts:
            # A successful bind resets the task's retry budget.
            self._attempts.pop(key, None)
        run = self.runs[wf_id]
        if run.first_start is None:
            run.first_start = self._now
        self.metrics.num_allocations += 1
        self.metrics.alloc_trace.append(
            (self._now, key, alloc.cpu, alloc.mem, alloc.scenario)
        )
        # Will this quota OOM? (§6.2.2: runtime memory floor + β)
        timing = self.cfg.timing
        runtime_floor = task.runtime_min_mem() + self.cfg.alloc.beta
        wall = timing.duration_multiplier * task.duration
        if alloc.mem < runtime_floor - 1e-9 and task.mem > 0:
            t_oom = self._now + timing.pod_startup_delay + \
                timing.oom_fraction * wall
            self._push(t_oom, EventKind.OOM, (pod.uid, wf_id))
        else:
            t_done = self._now + timing.pod_startup_delay + wall
            self._push(t_done, EventKind.COMPLETE, (pod.uid, wf_id))
        if self._vertical and not self._resize_armed \
                and task.usage_curve is not None:
            # First usage-curve pod on an idle controller: arm the
            # periodic sweep.  The controller re-arms itself while
            # resizable pods remain and disarms (in ``step``) when none
            # do, so a drained cluster queues no trailing RESIZE events.
            self._resize_armed = True
            self._push(self._now + self.cfg.vertical.check_interval,
                       EventKind.RESIZE, ())
        self._sample_usage()

    def _budget_exhausted(self, wf_id: str, task: TaskSpec) -> bool:
        """Count one attempted admission failure against the retry budget.

        Returns True once the task has failed more than ``max_retries``
        times since its last successful bind — the caller then terminates
        the whole workflow as a FAILED outcome.  With the default
        unbounded budget this is a no-op returning False.
        """
        budget = self._fault_cfg.max_retries
        if budget is None:
            return False
        key = f"{wf_id}/{task.task_id}"
        n = self._attempts.get(key, 0) + 1
        self._attempts[key] = n
        if n <= budget:
            return False
        self.metrics.failed_tasks.append((self._now, key))
        return True

    def _allocate_group(self, entries: List[Tuple[str, TaskSpec, str]],
                        include_pending: bool) -> None:
        """Decide a drained burst and apply the results in admission order.

        Graceful degradation rides the result application: every
        *attempted* failure counts against the task's retry budget (a
        blown budget marks the workflow dying — terminated after the
        pending queue is rebuilt, so the rebuild sees a consistent
        deque), and a round that made no progress arms the exponential
        backoff gate.  Which rows bind is untouched — decided rows of a
        dying workflow still bind (batched and replay modes already
        applied their in-scan debits identically) and are then killed by
        ``_fail_workflow``, keeping the two modes bit-for-bit.
        """
        if include_pending:
            entries = [(wf_id, task, "pending")
                       for wf_id, task in self._pending] + entries
        if not entries:
            return
        self.metrics.dispatched_rows += len(entries)
        self.metrics.num_dispatches += (
            1 if self.cfg.alloc.batch_allocation else len(entries))
        kept: Deque[Tuple[str, TaskSpec]] = deque()
        failed: List[Tuple[str, TaskSpec]] = []
        dying: Dict[str, None] = {}  # insertion-ordered workflow set
        bound_any = False
        waited_any = False
        rows = self._decision_rows(entries)
        for (wf_id, task, origin), (feasible, attempted, alloc) in zip(
                entries, rows):
            if feasible:
                self._bind(wf_id, task, alloc)
                bound_any = True
            elif origin == "pending":
                # Skipped rows (head-of-line) were never attempted and do
                # not count as waits, matching the sequential retry loop.
                if attempted:
                    self.metrics.num_waits += 1
                    waited_any = True
                    if self._budget_exhausted(wf_id, task):
                        dying[wf_id] = None
                        continue
                kept.append((wf_id, task))
            else:
                self.metrics.num_waits += 1
                waited_any = True
                if self._budget_exhausted(wf_id, task):
                    dying[wf_id] = None
                    continue
                failed.append((wf_id, task))
        if include_pending:
            kept.extend(failed)
            self._pending = kept
        else:
            self._pending.extend(failed)
        for wf_id in dying:
            if wf_id not in self._failed_workflows:
                self._fail_workflow(wf_id, "retry_budget")
        if bound_any:
            self._backoff_round = 0
            self._retry_gate = 0.0
        elif waited_any and self._pending \
                and self._fault_cfg.backoff_base > 0:
            # No progress this round: park the pending queue and schedule
            # the RETRY that reopens the gate — base * factor^round.
            delay = self._fault_cfg.backoff_base * \
                self._fault_cfg.backoff_factor ** self._backoff_round
            self._backoff_round += 1
            self._retry_gate = self._now + delay
            self._push(self._retry_gate, EventKind.RETRY, ("backoff",))

    def _drain_group(self, first: Event) -> None:
        """Fold the head's allocatable-event window into one burst.

        Events are consumed in heap order (time, kind, sequence), so the
        batch rows land in exactly the order the sequential loop would
        have decided them; virtual tasks complete inline, which may
        surface more in-window READY events — the loop keeps draining
        while the next queued event folds: an allocatable request due
        within ``batch_window`` seconds of the head ("decide at t+ε"),
        or a strictly-later INJECT within that deadline, which is
        injected inline so the jittered arrival's READY events join the
        burst.  The clock advances with each folded event, so the fused
        decision is made at the *last* arrival's timestamp, never before
        a request exists; a capacity-changing event inside the window
        (completion, deletion, OOM) stops the fold once the burst holds
        an undecided request, because it must apply first.  While the
        burst is still *empty* (no entries and no retried pending queue)
        strictly-later ``COMPLETE``/``DELETE`` events fold through — the
        freed capacity cannot change a decision that does not exist yet,
        so short-task streams stop fragmenting every window on their own
        completions (``OOM`` always anchors its own drain: it mutates a
        pod's outcome and schedules self-healing).  With
        ``batch_window=0.0`` the deadline is the head's own timestamp
        and only same-timestamp allocatable events fold — the seed's
        lockstep drain, bit for bit.  With forecasting enabled the
        window comes from :meth:`fold_window` instead — sized per burst
        from the predicted next inter-arrival gap.  Both engine modes
        share this drain; they differ only in how the group is decided
        (one fused dispatch vs the row-at-a-time replay — see
        ``_decision_rows``).
        """
        window = self.fold_window()
        if self._forecaster is not None and self._forecaster.ready:
            self.metrics.forecast_predictions += 1
            self.metrics.forecast_window_sum += window
        deadline = first.t + window
        include_pending = False
        entries: List[Tuple[str, TaskSpec, str]] = []
        event: Optional[Event] = first
        while event is not None:
            self._now = event.t
            if event.kind is EventKind.INJECT:
                self._inject(*event.payload)
            elif event.kind is EventKind.COMPLETE:
                # Folded only while the burst is idle (see below).
                self._complete(*event.payload)
            elif event.kind is EventKind.DELETE:
                self.cluster.delete(*event.payload)
            elif event.kind is EventKind.RETRY:
                # Backoff gate: retries scheduled before the gate reopens
                # leave the pending queue parked (the gate-time RETRY
                # pushed by the failed round reopens it).
                include_pending = self._now >= self._retry_gate
            elif event.kind is EventKind.READY:
                wf_id, tid = event.payload
                if wf_id in self._failed_workflows:
                    pass  # workflow already terminated FAILED
                else:
                    task = self.runs[wf_id].spec.tasks[tid]
                    if task.cpu == 0 and task.mem == 0:
                        # Virtual entrance/exit: complete instantly, no pod.
                        self._task_done(wf_id, tid)
                    else:
                        entries.append((wf_id, task, "ready"))
            else:  # HEAL
                wf_id, task = event.payload
                if wf_id not in self._failed_workflows:
                    self.metrics.realloc_events.append(
                        (self._now, f"{wf_id}/{task.task_id}")
                    )
                    entries.append((wf_id, task, "heal"))
            idle = not entries and not (include_pending and self._pending)
            event = self.queue.pop_mergeable(first.t, deadline,
                                             fold_capacity_free=idle)
        self._allocate_group(entries, include_pending)

    # --------------------------------------------------------- completion
    def _task_done(self, wf_id: str, tid: str) -> None:
        run = self.runs[wf_id]
        key = f"{wf_id}/{tid}"
        self.store.mark_done(key, self._now)
        run.done.add(tid)
        for child in run.spec.children(tid):
            run.indegree[child] -= 1
            if run.indegree[child] == 0:
                self._push(self._now, EventKind.READY, (wf_id, child))
        if run.complete:
            run.finished_at = self._now
            dur_start = run.first_start if run.first_start is not None \
                else run.injected_at
            self.metrics.workflow_durations[wf_id] = self._now - dur_start
            # SLA check (Eq. 4: workflow deadline = last task's deadline)
            if run.spec.deadline is not None \
                    and self._now > run.injected_at + run.spec.deadline:
                self.metrics.sla_violations.append(
                    (wf_id, self._now, run.injected_at + run.spec.deadline))

    def _stale(self, uid: int) -> bool:
        """A queued pod event whose pod was already terminated (killed by
        injected chaos or a workflow failure) — drop it."""
        pod = self.cluster.pods.get(uid)
        return pod is None or pod.phase is not PodPhase.RUNNING

    def _complete(self, uid: int, wf_id: str) -> None:
        if self._stale(uid):
            return
        pod = self.cluster.finish(uid, self._now, PodPhase.SUCCEEDED)
        self._sample_usage()
        self._push(self._now + self.cfg.timing.cleanup_delay,
                   EventKind.DELETE, (uid,))
        self._task_done(wf_id, pod.task.task_id)
        self._push(self._now, EventKind.RETRY, ())

    def _oom(self, uid: int, wf_id: str, forced: bool = False) -> None:
        """OOMKilled watch → delete → reallocate (self-healing, Fig. 9).

        With vertical adaptivity the kill is the *fallback*: an OOM-bound
        pod whose node has memory headroom is grown to its runtime floor
        in place instead (``_resize_rescue``) — no restart delay, no lost
        progress.  ``forced`` OOMs (injected storms — pressure beyond the
        quota's control) always kill.
        """
        if self._stale(uid):
            return
        vertical = self.cfg.vertical
        if vertical.enabled and vertical.resize_on_oom and not forced \
                and self._resize_rescue(uid, wf_id):
            return
        pod = self.cluster.finish(uid, self._now, PodPhase.OOM_KILLED)
        self._sample_usage()
        key = f"{wf_id}/{pod.task.task_id}"
        self.metrics.oom_events.append((self._now, key))
        self._push(self._now + self.cfg.timing.cleanup_delay,
                   EventKind.DELETE, (uid,))
        # Learn the runtime floor so the reallocation cannot repeat the OOM.
        learned = dataclasses.replace(
            pod.task, min_mem=max(pod.task.min_mem, pod.task.runtime_min_mem())
        )
        self._push(self._now + self.cfg.timing.restart_delay, EventKind.HEAL,
                   (wf_id, learned))

    # -------------------------------------------------- vertical adaptivity
    def _resize_rescue(self, uid: int, wf_id: str) -> bool:
        """Resize-first OOM policy (ARC-V): grow the quota in place.

        The §6.2.2 watch fired because the admitted memory quota sits
        below the runtime floor + β.  If the node's float64 books have
        headroom for the missing delta, the pod grows to the floor in
        place and runs to its *original* completion time — the kill, the
        cleanup/restart delays and the re-admission queue round-trip are
        all avoided.  Returns ``False`` when the node is full; the caller
        then falls back to the seed kill-and-reallocate path.
        """
        pod = self.cluster.pods[uid]
        task = pod.task
        need = task.runtime_min_mem() + self.cfg.alloc.beta
        if pod.quota.mem < need - 1e-9:
            head = self.cluster.node_headroom(pod.node)
            if need - pod.quota.mem > head.mem + 1e-9:
                return False  # node full: kill-and-reallocate
            old_mem = pod.quota.mem
            self.cluster.resize(uid, pod.quota.cpu, need)
            grown = pod.quota.mem - old_mem  # post-snap, matches the books
            self.metrics.num_resizes += 1
            self.metrics.num_grows += 1
            self.metrics.resize_events.append(
                (self._now, f"{wf_id}/{task.task_id}", 0.0, grown))
            self._sample_usage()
        # Quota now covers the floor (grown here, or already grown by an
        # earlier controller sweep): the kill is averted.
        self.metrics.resizes_avoided_oom += 1
        timing = self.cfg.timing
        t_done = pod.t_created + timing.pod_startup_delay + \
            timing.duration_multiplier * task.duration
        self._push(t_done, EventKind.COMPLETE, (uid, wf_id))
        return True

    def _any_resizable(self) -> bool:
        """A Running usage-curve pod exists — the controller has work."""
        return any(pod.phase is PodPhase.RUNNING
                   and pod.task.usage_curve is not None
                   for pod in self.cluster.pods.values())

    def _resize_tick(self) -> None:
        """One controller sweep: compare usage against quota, resize.

        For every Running usage-curve pod (uid order — deterministic) the
        target quota is the curve's *remaining-lifetime peak* usage plus
        the ``grow_margin`` headroom, floored at the acceptance minimum
        and (for memory) the §6.2.2 runtime floor + β so a shrink can
        never re-create the OOM condition admission cleared, and capped
        at the declared request.  Over-provisioned quotas shrink once
        they exceed the target by the ``shrink_margin`` hysteresis band;
        under-provisioned ones grow as far as the node's float64 headroom
        allows.  Shrinks credit ``reclaimed_*_seconds`` with the freed
        quota integrated over the pod's remaining lifetime and schedule a
        same-time RETRY (RESIZE sorts before RETRY) so the pending queue
        decides against the reclaimed capacity immediately.
        """
        cfg = self.cfg.vertical
        timing = self.cfg.timing
        beta = self.cfg.alloc.beta
        from repro import vertical as curves

        changed = False
        shrank = False
        for uid in sorted(self.cluster.pods):
            pod = self.cluster.pods[uid]
            task = pod.task
            if pod.phase is not PodPhase.RUNNING or task.usage_curve is None:
                continue
            wall = timing.duration_multiplier * task.duration
            if wall <= 0:
                continue
            p = (self._now - pod.t_started - timing.pod_startup_delay) / wall
            if p >= 1.0:
                continue  # completing at this instant
            p = max(p, 0.0)
            peak_cpu, peak_mem = curves.peak_usage(task, p)
            floor_cpu = task.min_cpu
            floor_mem = max(task.min_mem, task.runtime_min_mem() + beta) \
                if task.mem > 0 else 0.0
            want_cpu = min(max(peak_cpu * (1.0 + cfg.grow_margin),
                               floor_cpu), max(task.cpu, floor_cpu))
            want_mem = min(max(peak_mem * (1.0 + cfg.grow_margin),
                               floor_mem), max(task.mem, floor_mem))
            q_cpu, q_mem = pod.quota.cpu, pod.quota.mem
            new_cpu, new_mem = q_cpu, q_mem
            if q_cpu > want_cpu * (1.0 + cfg.shrink_margin) \
                    or want_cpu > q_cpu:
                new_cpu = want_cpu
            if q_mem > want_mem * (1.0 + cfg.shrink_margin) \
                    or want_mem > q_mem:
                new_mem = want_mem
            # Grows are bounded by the node's remaining headroom (the
            # resize itself re-checks against the authoritative books).
            if new_cpu > q_cpu or new_mem > q_mem:
                head = self.cluster.node_headroom(pod.node)
                new_cpu = min(new_cpu, q_cpu + max(head.cpu, 0.0)) \
                    if new_cpu > q_cpu else new_cpu
                new_mem = min(new_mem, q_mem + max(head.mem, 0.0)) \
                    if new_mem > q_mem else new_mem
            # ClusterSim.resize snaps quotas onto the float32 lattice
            # (the pod slot arrays are float32); snap here too so the
            # telemetry deltas below equal the books' deltas exactly.
            new_cpu = float(np.float32(new_cpu))
            new_mem = float(np.float32(new_mem))
            if abs(new_cpu - q_cpu) < 1e-9 and abs(new_mem - q_mem) < 1e-9:
                continue
            self.cluster.resize(uid, new_cpu, new_mem)
            changed = True
            self.metrics.num_resizes += 1
            if new_cpu < q_cpu or new_mem < q_mem:
                self.metrics.num_shrinks += 1
            if new_cpu > q_cpu or new_mem > q_mem:
                self.metrics.num_grows += 1
            remaining = (1.0 - p) * wall
            if new_cpu < q_cpu:
                self.metrics.reclaimed_cpu_seconds += \
                    (q_cpu - new_cpu) * remaining
                shrank = True
            if new_mem < q_mem:
                self.metrics.reclaimed_mem_seconds += \
                    (q_mem - new_mem) * remaining
                shrank = True
            self.metrics.resize_events.append(
                (self._now, f"{pod.workflow_id}/{task.task_id}",
                 new_cpu - q_cpu, new_mem - q_mem))
        if changed:
            self._sample_usage()
        if shrank:
            self._push(self._now, EventKind.RETRY, ())
        # Re-arm unconditionally; a sweep that finds nothing resizable is
        # dropped (and disarmed) by the guard in ``step`` without
        # advancing the clock, so trailing RESIZE events cannot stretch
        # the makespan.
        self._push(self._now + cfg.check_interval, EventKind.RESIZE, ())

    # ------------------------------------------------------- fault handling
    def _node_down(self, node: int) -> None:
        """Injected NODE_DOWN: cordon the node, displace its pods.

        Each displaced Running pod terminates ``FAILED`` (inside
        ``ClusterSim.set_node_down``), is cleaned up like any terminal
        pod, and its *original* task re-enters admission through the HEAL
        path after ``restart_delay`` — the same self-healing road an
        OOMKilled pod takes, minus the learned floor (the task itself was
        fine; its node was not).
        """
        displaced = self.cluster.set_node_down(node, self._now)
        if displaced is None:  # already offline
            return
        self.metrics.node_events.append((self._now, node, "down"))
        self._sample_usage()
        timing = self.cfg.timing
        for pod in displaced:
            key = f"{pod.workflow_id}/{pod.task.task_id}"
            self.metrics.displaced_tasks.append((self._now, key))
            self._push(self._now + timing.cleanup_delay,
                       EventKind.DELETE, (pod.uid,))
            if pod.workflow_id in self._failed_workflows:
                continue
            self._displaced_at.setdefault(key, self._now)
            heal_task = pod.task
            if pod.resized:
                # A resized pod re-enters admission at its *current*
                # quota, not the stale declared request — the vertical
                # controller's sizing survives displacement.
                heal_task = dataclasses.replace(
                    heal_task,
                    cpu=max(pod.quota.cpu, heal_task.min_cpu),
                    mem=max(pod.quota.mem, heal_task.min_mem))
            self._push(self._now + timing.restart_delay, EventKind.HEAL,
                       (pod.workflow_id, heal_task))

    def _node_up(self, node: int) -> None:
        """Injected NODE_UP: restore the node, retry against it.

        The same-time RETRY sorts after NODE_UP (kind order), so pending
        tasks decide against the recovered capacity immediately.
        """
        if not self.cluster.set_node_up(node):  # was not offline
            return
        self.metrics.node_events.append((self._now, node, "up"))
        self._sample_usage()
        self._push(self._now, EventKind.RETRY, ())

    def _oom_storm(self, victims: int) -> None:
        """Injected OOM_STORM: force-OOM the longest-running pods.

        Victims are the lowest-uid Running pods — creation order, so the
        choice is deterministic for a seeded run.  Each goes through the
        ordinary ``_oom`` self-healing path; its still-queued COMPLETE
        event goes stale and is dropped by the guard.
        """
        running = sorted(uid for uid, pod in self.cluster.pods.items()
                         if pod.phase is PodPhase.RUNNING)
        for uid in running[:victims]:
            # forced: storm pressure is beyond the quota's control, so
            # the resize-first rescue never applies — the victim dies.
            self._oom(uid, self.cluster.pods[uid].workflow_id, forced=True)

    def _wf_deadline(self, wf_id: str) -> None:
        """Per-workflow deadline check: incomplete -> FAILED outcome."""
        run = self.runs.get(wf_id)
        if run is None or run.complete \
                or wf_id in self._failed_workflows:
            return
        self._fail_workflow(wf_id, "deadline")

    def _fail_workflow(self, wf_id: str, reason: str) -> None:
        """Terminate a workflow as a FAILED outcome (graceful degradation).

        Its queued tasks leave the pending queue, its Running pods are
        killed (``FAILED`` + cleanup), and its unfinished task records go
        numerically inert via ``mark_done`` so the allocator's demand
        window no longer prices them in.  The workflow is *not* added to
        ``workflow_durations`` — completed-workflow statistics stay
        completed-only; it is counted on ``metrics.failed_workflows``.
        """
        self._failed_workflows.add(wf_id)
        self.metrics.failed_workflows.append((self._now, wf_id, reason))
        if self._pending:
            self._pending = deque(
                (w, t) for w, t in self._pending if w != wf_id)
        victims = [pod for pod in self.cluster.pods.values()
                   if pod.workflow_id == wf_id
                   and pod.phase is PodPhase.RUNNING]
        for pod in victims:
            self.cluster.finish(pod.uid, self._now, PodPhase.FAILED)
            self._push(self._now + self.cfg.timing.cleanup_delay,
                       EventKind.DELETE, (pod.uid,))
        run = self.runs[wf_id]
        run.finished_at = self._now
        for tid in run.spec.tasks:
            if tid not in run.done:
                self.store.mark_done(f"{wf_id}/{tid}", self._now)
        if victims:
            self._sample_usage()
            # Freed capacity: let the pending queue retry against it.
            self._push(self._now, EventKind.RETRY, ())

    # ------------------------------------------------------------ run loop
    def _event_stale(self, event: Event) -> bool:
        """Queued events whose subject already terminated are no-ops.

        They are dropped *before* the clock advances, so a trailing
        deadline check for a long-completed workflow, a COMPLETE for a
        chaos-killed pod, or a backoff retry with nothing left pending
        cannot inflate the makespan (only consulted when faults are
        configured — without them no event ever goes stale).
        """
        kind = event.kind
        if kind is EventKind.COMPLETE or kind is EventKind.OOM:
            return self._stale(event.payload[0])
        if kind is EventKind.WF_DEADLINE:
            wf_id = event.payload[0]
            run = self.runs.get(wf_id)
            return run is None or run.complete \
                or wf_id in self._failed_workflows
        if kind is EventKind.RETRY and event.payload == ("backoff",):
            return not self._pending
        return False

    def step(self) -> Event:
        """Pop and process the next event; returns the processed head.

        An allocatable head (retry/ready/heal) drains its whole
        ``batch_window`` of follow-on requests in the same step — see
        ``_drain_group``.  Exposed so harnesses (benchmarks, tests) can
        drive the engine event by event instead of to completion.
        """
        if not self.queue:
            raise RuntimeError("step() on an empty event queue — guard "
                               "the loop with `while engine.queue: ...`")
        event = self.queue.pop()
        if self._chaos_on and self._event_stale(event):
            return event
        if event.kind is EventKind.RESIZE and not self._any_resizable():
            # Quiescent controller: drop the sweep *before* the clock
            # advances (a trailing RESIZE must not stretch the makespan)
            # and disarm — the next usage-curve bind re-arms it.
            self._resize_armed = False
            return event
        if event.t > self.cfg.timing.max_time:
            raise RuntimeError("simulation exceeded max_time — deadlock?")
        self._now = event.t
        if self._t_first is None:
            self._t_first = event.t
        if event.kind is EventKind.INJECT:
            self._inject(*event.payload)
        elif event.kind is EventKind.COMPLETE:
            self._complete(*event.payload)
        elif event.kind is EventKind.OOM:
            self._oom(*event.payload)
        elif event.kind is EventKind.OOM_STORM:
            self._oom_storm(*event.payload)
        elif event.kind is EventKind.DELETE:
            self.cluster.delete(*event.payload)
        elif event.kind is EventKind.NODE_DOWN:
            self._node_down(*event.payload)
        elif event.kind is EventKind.NODE_UP:
            self._node_up(*event.payload)
        elif event.kind is EventKind.WF_DEADLINE:
            self._wf_deadline(*event.payload)
        elif event.kind is EventKind.RESIZE:
            self._resize_tick()
        else:  # RETRY / READY / HEAL
            self._drain_group(event)
        return event

    def finalize(self) -> EngineMetrics:
        """Deadlock check + final metrics — the epilogue of ``run()``.

        Public so harnesses that drive ``step()`` themselves (the
        streaming engine, benchmarks) finish a drained run identically
        to ``run()``.
        """
        incomplete = [w for w, r in self.runs.items()
                      if not r.complete and w not in self._failed_workflows]
        if incomplete or self._pending:
            raise RuntimeError(
                f"deadlocked workflows: {incomplete}, pending={len(self._pending)}"
            )
        self._sample_usage()
        total = self._now - (self._t_first or 0.0)
        self.metrics.makespan = total
        if total > 0:
            self.metrics.avg_cpu_usage = float(self._util_integral[0] / total)
            self.metrics.avg_mem_usage = float(self._util_integral[1] / total)
        return self.metrics

    def run(self) -> EngineMetrics:
        while self.queue:
            self.step()
            if self.cfg.invariant_checks:
                self.cluster.check_invariants()
        return self.finalize()


def run_experiment(
    workflow_kind: str,
    pattern: List[Tuple[float, int]],
    allocator: str,
    seed: int = 0,
    config: Optional[EngineConfig] = None,
    task_kwargs: Optional[dict] = None,
) -> EngineMetrics:
    """Inject `pattern` bursts of `workflow_kind` and run to completion."""
    from repro.workflows.dags import WORKFLOW_BUILDERS

    cfg = (config or EngineConfig()).evolve(allocator=allocator)
    engine = KubeAdaptor(cfg)
    rng = np.random.default_rng(seed)
    builder = WORKFLOW_BUILDERS[workflow_kind]
    idx = 0
    for t, count in pattern:
        for _ in range(count):
            spec = builder(f"{workflow_kind}-{idx}", rng, task_kwargs)
            engine.submit(spec, t)
            idx += 1
    return engine.run()
