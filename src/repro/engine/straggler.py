"""Straggler mitigation: speculative re-execution of slow tasks.

At fleet scale the tail latency of task pods (slow node, contended
NIC, flaky HBM) dominates workflow makespan.  The monitor compares each
running pod's elapsed time to the p-quantile of completed durations for
the same task family; tasks exceeding ``threshold × p95`` get a
speculative duplicate on the max-residual node, and the first finisher
wins (the loser is cancelled) — the classic MapReduce backup-task
strategy, here as a MAPE-K Analyse/Plan extension.

``SpeculativeMonitor`` is engine-agnostic: the simulator calls
``observe``/``check`` on its event loop; ``tests/test_straggler.py``
validates the win on a synthetic heavy-tail duration distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SpeculativeMonitor:
    threshold: float = 1.5  # speculate beyond threshold × p95
    quantile: float = 0.95
    min_samples: int = 8
    max_inflight_fraction: float = 0.1  # budget for duplicates

    completed: List[float] = dataclasses.field(default_factory=list)
    speculated: Dict[str, float] = dataclasses.field(default_factory=dict)

    def observe(self, duration: float) -> None:
        self.completed.append(duration)

    def p95(self) -> Optional[float]:
        if len(self.completed) < self.min_samples:
            return None
        return float(np.quantile(self.completed, self.quantile))

    def should_speculate(self, task_key: str, elapsed: float,
                         inflight: int, running: int) -> bool:
        """Plan phase: duplicate `task_key` if it's a straggler and the
        duplicate budget allows."""
        p = self.p95()
        if p is None or task_key in self.speculated:
            return False
        if running and inflight / running > self.max_inflight_fraction:
            return False
        if elapsed > self.threshold * p:
            self.speculated[task_key] = elapsed
            return True
        return False


def simulate_makespan(durations: np.ndarray, slots: int,
                      monitor: Optional[SpeculativeMonitor] = None,
                      backup_speed: float = 1.0,
                      rng: Optional[np.random.Generator] = None
                      ) -> float:
    """Greedy list-scheduling makespan, optionally with speculation.

    Tasks run on `slots` lanes; when a monitor is given, a straggling
    task spawns a backup drawn from the *typical* (p50) duration — the
    straggler's slowness is environmental (slow node), not intrinsic,
    so the backup on a healthy node finishes around the median.
    """
    rng = rng or np.random.default_rng(0)
    lanes = np.zeros(slots)
    finished = []
    median = float(np.median(durations))
    for d in durations:
        lane = int(np.argmin(lanes))
        start = lanes[lane]
        eff = d
        if monitor is not None:
            p = monitor.p95()
            if p is not None and d > monitor.threshold * p:
                # backup launched at threshold×p95; first finisher wins
                backup = median / backup_speed
                eff = min(d, monitor.threshold * p + backup)
            monitor.observe(min(d, eff))
        else:
            finished.append(d)
        lanes[lane] = start + eff
    return float(lanes.max())
