"""The engine's event subsystem: typed taxonomy + windowed drain queue.

``KubeAdaptor`` used to keep its discrete-event machinery inline —
module-level int constants and raw ``heapq`` calls on a private list.
This module extracts it into a small, testable subsystem:

* :class:`EventKind` — the typed event taxonomy.  Ordering is load
  bearing: at equal timestamps, deletions/completions sort before
  retries before arrivals so released resources are visible to retries,
  and ``HEAL`` sorts after same-time ``READY`` events (preserving the
  seed engine's admission order for self-healed tasks).
* :class:`Event` — one scheduled occurrence, ``(t, kind, seq, payload)``.
  ``seq`` is a per-queue monotone counter, so events at the same
  ``(t, kind)`` pop in FIFO push order and the payload is never compared.
* :class:`EventQueue` — a priority queue over :class:`Event` with one
  extra primitive, :meth:`EventQueue.pop_mergeable`: pop the head *iff*
  it can fold into the burst being drained — an allocatable request
  (retry/ready/heal) due at or before a deadline, or a *later* ``INJECT``
  within the deadline (injection creates READY events without touching
  cluster capacity, so jittered arrival streams fold through it).  The
  engine's drain loop uses it to fold every allocatable event within the
  fold window of the head event into a single fused ``allocate_batch``
  dispatch ("decide at t+ε").  The window is
  ``TimingConfig.batch_window`` seconds, or — when a forecast is enabled
  — whatever ``KubeAdaptor.fold_window()`` derives from the predicted
  inter-arrival gap.  With a zero-width window the deadline is the
  head's own timestamp, so only
  same-timestamp allocatable events fold (and the inject clause, which
  requires a strictly later timestamp, can never fire) — bit-for-bit the
  legacy drain.

The fold is otherwise *contiguous*: a capacity-changing event (e.g. a
``COMPLETE`` inside the window) stops the merge once the burst holds an
undecided request, because it must be applied before any later
allocation decision.  While the burst is still *empty* the engine opts
in to folding through strictly-later ``COMPLETE``/``DELETE`` events
(``fold_capacity_free``) — freed capacity cannot change a decision that
does not exist yet, and short-task streams stop fragmenting into tiny
dispatches on their own completions.
"""
from __future__ import annotations

import enum
import heapq
import itertools
from typing import List, NamedTuple, Optional, Tuple


class EventKind(enum.IntEnum):
    """Engine event taxonomy; the integer values define heap order.

    The chaos kinds (``OOM_STORM``/``NODE_DOWN``/``NODE_UP``/
    ``WF_DEADLINE``) sort between the pod-lifecycle events and the
    allocatable requests: at equal timestamps an injected fault (and the
    capacity it removes or restores) is applied *before* any same-time
    retry or arrival decides against the cluster.  None of them ever
    folds into a drained burst — like ``OOM`` they mutate pod/workflow
    outcomes, so each anchors its own drain.

    ``RESIZE`` (the vertical controller's periodic sweep) is likewise
    capacity-changing — a shrink frees quota, a grow consumes headroom —
    so it too anchors its own drain, and it sorts *before* same-time
    ``RETRY``: capacity reclaimed by a shrink is visible to the retry
    pass the controller schedules at the same timestamp.
    """

    COMPLETE = 0   # pod ran to completion
    OOM = 1        # pod OOMKilled mid-run (§6.2.2)
    OOM_STORM = 2  # injected fault: force-OOM k running pods (repro.chaos)
    DELETE = 3     # Task Container Cleaner removes a terminal pod
    NODE_DOWN = 4  # injected fault: a node goes offline (capacity loss)
    NODE_UP = 5    # injected fault: an offline node recovers
    WF_DEADLINE = 6  # per-workflow deadline check -> FAILED outcome
    RESIZE = 7     # vertical controller tick: in-place resize sweep (ARC-V)
    RETRY = 8      # re-attempt the pending queue
    INJECT = 9     # Workflow Injection Module delivers a workflow
    READY = 10     # a task's dependencies are satisfied
    HEAL = 105     # self-healing re-allocation; sorts after same-time READY


# Allocatable task requests: the kinds the drain folds into one fused
# allocate_batch dispatch.
ALLOCATABLE = frozenset((EventKind.RETRY, EventKind.READY, EventKind.HEAL))


class Event(NamedTuple):
    """One scheduled occurrence.  Tuple order == heap priority."""

    t: float
    kind: EventKind
    seq: int
    payload: Tuple = ()


class EventQueue:
    """Priority queue of :class:`Event` with a windowed-drain primitive."""

    def __init__(self) -> None:
        self._heap: List[Event] = []
        self._seq = itertools.count()

    def push(self, t: float, kind: EventKind, payload: Tuple = ()) -> Event:
        event = Event(t, kind, next(self._seq), payload)
        heapq.heappush(self._heap, event)
        return event

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def pop_mergeable(self, head_t: float, deadline: float,
                      fold_capacity_free: bool = False) -> Optional[Event]:
        """Pop the head iff it can fold into the burst drained at
        ``head_t`` with fold deadline ``deadline`` (= ``head_t`` plus the
        engine's fold window — static ``batch_window`` or the
        forecast-derived width from ``KubeAdaptor.fold_window()``).

        Foldable heads are (a) allocatable requests (retry/ready/heal)
        due at or before the deadline, and (b) ``INJECT`` events strictly
        later than ``head_t`` but within the deadline — the engine
        injects those inline so a jittered arrival's READY events join
        the burst.  The strict inequality keeps a same-timestamp INJECT
        out of the fold, exactly as the legacy same-timestamp drain
        ordered it (and makes clause (b) unreachable at
        ``batch_window=0``).

        ``fold_capacity_free=True`` adds clause (c): a strictly-later
        ``COMPLETE`` or ``DELETE`` within the deadline folds too.  The
        engine passes it only while the drained burst holds *no* undecided
        request, so the freed capacity cannot change an in-flight
        decision — it keeps short-task streams from fragmenting every
        window on their own completions.  ``OOM`` never folds: it mutates
        a pod's outcome (self-healing) and anchors its own drain.  Like
        clause (b), the strict inequality makes it unreachable at
        ``batch_window=0``.

        Anything else — a capacity-changing event the caller must apply
        first, or any event beyond the deadline — returns ``None`` and
        stays queued.
        """
        head = self.peek()
        if head is None or head.t > deadline:
            return None
        if head.kind in ALLOCATABLE:
            return heapq.heappop(self._heap)
        foldable_later = (EventKind.INJECT, EventKind.COMPLETE,
                          EventKind.DELETE) if fold_capacity_free \
            else (EventKind.INJECT,)
        if head.kind in foldable_later and head.t > head_t:
            return heapq.heappop(self._heap)
        return None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"EventQueue(len={len(self._heap)}, next={self.peek()})"
