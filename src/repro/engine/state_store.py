"""Knowledge base — the Redis analogue (paper Eq. 8, §4.2).

Holds one record per task: ``{t_start, duration, t_end, cpu, mem, flag}``.
``t_start`` is the *projected* earliest start (critical-path estimate from
the Plan phase) until the task actually launches, then the actual start —
this is what lets Alg. 1 see future in-window competitors (Fig. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.types import TaskWindow


@dataclasses.dataclass
class TaskRecord:
    key: str  # f"{workflow_id}/{task_id}"
    t_start: float  # projected until launched, then actual
    duration: float
    cpu: float
    mem: float
    t_end: float = 0.0
    flag: bool = False  # True once complete (Eq. 8)


class StateStore:
    """Map<task.id, task_record> with an array view for the JAX window."""

    def __init__(self) -> None:
        self._records: Dict[str, TaskRecord] = {}

    def put(self, rec: TaskRecord) -> None:
        self._records[rec.key] = rec

    def get(self, key: str) -> Optional[TaskRecord]:
        return self._records.get(key)

    def mark_started(self, key: str, t_start: float) -> None:
        rec = self._records[key]
        rec.t_start = t_start
        rec.t_end = t_start + rec.duration

    def mark_done(self, key: str, t_end: float) -> None:
        rec = self._records[key]
        rec.flag = True
        rec.t_end = t_end

    def window(self, exclude: Optional[str] = None) -> TaskWindow:
        """Struct-of-arrays view for Alg. 1 (excluding the requester)."""
        recs = [r for k, r in self._records.items() if k != exclude]
        return TaskWindow(
            t_start=np.array([r.t_start for r in recs], np.float32),
            cpu=np.array([r.cpu for r in recs], np.float32),
            mem=np.array([r.mem for r in recs], np.float32),
            done=np.array([r.flag for r in recs], bool),
        )

    def __len__(self) -> int:
        return len(self._records)
