"""Knowledge base — the Redis analogue (paper Eq. 8, §4.2).

Holds one record per task: ``{t_start, duration, t_end, cpu, mem, flag}``.
``t_start`` is the *projected* earliest start (critical-path estimate from
the Plan phase) until the task actually launches, then the actual start —
this is what lets Alg. 1 see future in-window competitors (Fig. 1).

The array view is **append-only with dirty-slot updates**: each record
gets a permanent slot in power-of-two-capacity float32 arrays, and
``mark_started`` / ``mark_done`` write that slot in place.  ``window()``
therefore returns the persistent capacity-sized arrays (free tail slots
are ``done=True`` with zero demand — numerically inert under the masked
reduction) instead of rebuilding Python lists per request, and the JIT
shapes the allocator sees only change when capacity doubles.  Requesters
exclude their own record by slot index (``index_of``) rather than by
filtering, so every caller shares the same arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.types import TaskWindow


@dataclasses.dataclass
class TaskRecord:
    key: str  # f"{workflow_id}/{task_id}"
    t_start: float  # projected until launched, then actual
    duration: float
    cpu: float
    mem: float
    t_end: float = 0.0
    flag: bool = False  # True once complete (Eq. 8)


class StateStore:
    """Map<task.id, task_record> with an array view for the JAX window."""

    def __init__(self) -> None:
        self._records: Dict[str, TaskRecord] = {}
        self._slots: Dict[str, int] = {}
        self._count = 0
        self._capacity = 0
        self._t_start = np.zeros((0,), np.float32)
        self._cpu = np.zeros((0,), np.float32)
        self._mem = np.zeros((0,), np.float32)
        self._done = np.zeros((0,), bool)

    def _grow(self) -> None:
        new_cap = max(1, self._capacity * 2)
        for name, fill in (("_t_start", 0.0), ("_cpu", 0.0), ("_mem", 0.0),
                           ("_done", True)):
            old = getattr(self, name)
            grown = np.full((new_cap,), fill, old.dtype)
            grown[: self._capacity] = old
            setattr(self, name, grown)
        self._capacity = new_cap

    def put(self, rec: TaskRecord) -> None:
        slot = self._slots.get(rec.key)
        if slot is None:
            if self._count == self._capacity:
                self._grow()
            slot = self._count
            self._count += 1
            self._slots[rec.key] = slot
        self._records[rec.key] = rec
        self._t_start[slot] = rec.t_start
        self._cpu[slot] = rec.cpu
        self._mem[slot] = rec.mem
        self._done[slot] = rec.flag

    def get(self, key: str) -> Optional[TaskRecord]:
        return self._records.get(key)

    def index_of(self, key: str) -> int:
        """Record slot in the array view (for self-exclusion masks)."""
        return self._slots[key]

    def mark_started(self, key: str, t_start: float) -> None:
        rec = self._records[key]
        rec.t_start = t_start
        rec.t_end = t_start + rec.duration
        self._t_start[self._slots[key]] = t_start

    def mark_done(self, key: str, t_end: float) -> None:
        rec = self._records[key]
        rec.flag = True
        rec.t_end = t_end
        self._done[self._slots[key]] = True

    def window(self, exclude: Optional[str] = None) -> TaskWindow:
        """Struct-of-arrays view for Alg. 1.

        Without ``exclude`` this is the persistent capacity-sized view
        (zero copies; treat as read-only) — pair it with ``index_of`` to
        mask the requester.  The ``exclude`` form is the legacy API and
        materializes a filtered copy.
        """
        if exclude is None:
            return TaskWindow(
                t_start=self._t_start, cpu=self._cpu, mem=self._mem,
                done=self._done,
            )
        keep = np.ones((self._capacity,), bool)
        slot = self._slots.get(exclude)
        if slot is not None:
            keep[slot] = False
        keep[self._count:] = False
        return TaskWindow(
            t_start=self._t_start[keep], cpu=self._cpu[keep],
            mem=self._mem[keep], done=self._done[keep],
        )

    def __len__(self) -> int:
        return len(self._records)
