"""ML workloads as workflow tasks — the paper's technique driving real
JAX jobs (DESIGN §2, workload plane).

A ``MLTaskSpec`` wraps a training job (arch config + steps + token
budget) as a workflow task whose resources are (chip-milliseconds,
HBM MiB).  The ARAS quota maps onto the job's *microbatch size*: memory
is the incompressible resource (activations must fit the quota), compute
the compressible one — exactly the paper's CPU/memory split.  An
OOMKilled job (quota below the activation floor) self-heals by halving
the microbatch and restarting from its last checkpoint — Fig. 9
semantics on the workload plane.

``run_ml_workflow`` executes a DAG of training jobs under ARAS on the
local device, with per-job checkpointing. Used by
``examples/train_lm.py`` and ``tests/test_mljobs.py``.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.allocator import AdaptiveAllocator
from repro.core.types import Allocation, ClusterSnapshot, TaskSpec, TaskWindow
from repro.data.synthetic import SyntheticDataset
from repro.models.api import build_model
from repro.models.config import ModelConfig
from repro.optim import make_optimizer
from repro.training import LoopConfig, train


@dataclasses.dataclass
class MLTaskSpec:
    """A training job as a workflow task."""

    task_id: str
    cfg: ModelConfig
    steps: int
    batch: int  # requested global batch (the 'cpu'-like knob)
    seq: int
    mem_mib_per_seq: float = 8.0  # activation footprint per sequence
    min_batch: int = 1
    depends_on: Tuple[str, ...] = ()

    def as_task(self) -> TaskSpec:
        return TaskSpec(
            task_id=self.task_id,
            image=f"jax-train:{self.cfg.name}",
            cpu=float(self.batch),  # compressible: batch lanes
            mem=self.batch * self.mem_mib_per_seq,  # incompressible
            duration=float(self.steps),
            min_cpu=float(self.min_batch),
            min_mem=self.min_batch * self.mem_mib_per_seq,
        )


@dataclasses.dataclass
class MLJobResult:
    task_id: str
    batch_used: int
    final_loss: float
    restarts: int
    wall_s: float


def run_ml_workflow(
    jobs: List[MLTaskSpec],
    *,
    cluster_mem: float = 256.0,  # MiB of "HBM" the allocator manages
    ckpt_root: str = "/tmp/repro_mljobs",
    seed: int = 0,
    inject_oom_once: bool = False,
) -> Dict[str, MLJobResult]:
    """Execute a DAG of training jobs under ARAS quota control."""
    allocator = AdaptiveAllocator()
    done: Dict[str, MLJobResult] = {}
    pending = {j.task_id: j for j in jobs}
    running_quota: List[Tuple[str, float]] = []  # (task, mem quota)

    def snapshot() -> ClusterSnapshot:
        used = [m for _, m in running_quota]
        return ClusterSnapshot(
            allocatable_cpu=np.array([1e9], np.float32),
            allocatable_mem=np.array([cluster_mem], np.float32),
            pod_node=np.zeros((len(used),), np.int32),
            pod_cpu=np.ones((len(used),), np.float32),
            pod_mem=np.array(used, np.float32),
            pod_active=np.ones((len(used),), bool),
        )

    def window() -> TaskWindow:
        waiting = [j.as_task() for j in pending.values()]
        return TaskWindow(
            t_start=np.zeros((len(waiting),), np.float32),
            cpu=np.array([t.cpu for t in waiting], np.float32),
            mem=np.array([t.mem for t in waiting], np.float32),
            done=np.zeros((len(waiting),), bool),
        )

    oom_injected = [not inject_oom_once]
    order = _topo_order(jobs)
    for tid in order:
        job = pending.pop(tid)
        task = job.as_task()
        alloc = allocator.allocate(task, snapshot(), window(), now=0.0)
        # vertical scaling: quota -> microbatch lanes
        batch = max(job.min_batch,
                    min(job.batch, int(alloc.mem / job.mem_mib_per_seq)))
        restarts = 0
        ckpt = os.path.join(ckpt_root, tid)
        shutil.rmtree(ckpt, ignore_errors=True)
        t0 = time.time()
        while True:
            try:
                if not oom_injected[0] and restarts == 0:
                    oom_injected[0] = True
                    raise MemoryError("injected HBM OOM")
                model = build_model(job.cfg)
                opt = make_optimizer("adamw", learning_rate=3e-3)
                ds = SyntheticDataset(job.cfg, batch=batch, seq=job.seq,
                                      seed=seed)
                lc = LoopConfig(total_steps=job.steps,
                                checkpoint_every=max(1, job.steps // 4),
                                checkpoint_dir=ckpt, log_every=10 ** 9)
                train(model, opt, ds, lc)
                loss = train.last_history[-1]
                break
            except MemoryError:
                # MAPE-K self-healing: halve the microbatch, restart from
                # the latest checkpoint (loop restores automatically).
                restarts += 1
                batch = max(job.min_batch, batch // 2)
        running_quota.append((tid, batch * job.mem_mib_per_seq))
        done[tid] = MLJobResult(task_id=tid, batch_used=batch,
                                final_loss=float(loss), restarts=restarts,
                                wall_s=time.time() - t0)
    return done


def _topo_order(jobs: List[MLTaskSpec]) -> List[str]:
    by_id = {j.task_id: j for j in jobs}
    seen: Dict[str, int] = {}
    order: List[str] = []

    def visit(tid: str):
        if seen.get(tid) == 2:
            return
        if seen.get(tid) == 1:
            raise ValueError("cycle in ML job DAG")
        seen[tid] = 1
        for dep in by_id[tid].depends_on:
            visit(dep)
        seen[tid] = 2
        order.append(tid)

    for j in jobs:
        visit(j.task_id)
    return order
