"""Usage-curve models for vertical adaptivity (ARC-V).

The paper's allocator fixes a pod's quota at admission: declared request
in, granted quota out, and the record never changes until completion (or
the §6.2.2 OOM-kill/reallocate detour).  That model cannot express the
waste ARC-V targets — a pod whose *actual* consumption diverges from its
admitted quota over its lifetime, stranding residual capacity the
cluster could re-admit pending work into.

This module makes declared ≠ used a first-class scenario family:

* a :data:`repro.api.registry.CURVES` registry of **usage-curve models**
  — seed-deterministic functions of lifetime progress ``p ∈ [0, 1]``
  returning the fraction of the declared request the task really uses at
  that point.  Built-ins: ``constant`` (flat fraction), ``ramp`` (linear
  start→end), ``step`` (piecewise phases), ``bursty`` (low baseline with
  seed-placed high bursts).
* :func:`attach_usage` — stamp a curve onto every non-virtual task of a
  :class:`~repro.workflows.spec.WorkflowSpec`; per-task seeds are
  derived deterministically so ``bursty`` curves differ across tasks but
  replay bit for bit.
* :func:`usage_at` / :func:`peak_usage` — the engine-facing sampling
  API.  ``peak_usage(task, p)`` is the maximum usage over the task's
  *remaining* lifetime ``[p, 1]`` — the quantity the vertical controller
  in ``KubeAdaptor`` sizes quotas against: shrinking to the remaining
  peak (plus a hysteresis margin) can never starve a deterministic
  curve later in life.

Curves are *models of truth*, not measurements: the controller treats
them as an oracle for what the pod consumes, the same way
``actual_min_mem`` models the Stress program's real footprint for the
Fig-9 OOM experiments.

A curve object needs two methods::

    value(p)  -> fraction of the declared request in use at progress p
    peak(p0)  -> max over p in [p0, 1] of value(p)

Fractions are clamped to be non-negative but may exceed 1.0 — a task can
use more than it declared, which is exactly the under-provisioned case
the grow path (and resize-first OOM rescue) exists for.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Mapping, Optional, Tuple

import numpy as np

from repro.api.registry import CURVES
from repro.core.types import TaskSpec
from repro.workflows.spec import WorkflowSpec


# --------------------------------------------------------------- curves
@dataclasses.dataclass(frozen=True)
class ConstantCurve:
    """Flat usage at a fixed fraction of the declared request."""

    frac: float

    def value(self, p: float) -> float:
        return self.frac

    def peak(self, p0: float) -> float:
        return self.frac


@dataclasses.dataclass(frozen=True)
class RampCurve:
    """Linear interpolation ``start`` → ``end`` over the lifetime."""

    start: float
    end: float

    def value(self, p: float) -> float:
        p = min(max(p, 0.0), 1.0)
        return self.start + (self.end - self.start) * p

    def peak(self, p0: float) -> float:
        # Linear: the max over [p0, 1] sits at an endpoint.
        return max(self.value(p0), self.end)


@dataclasses.dataclass(frozen=True)
class StepCurve:
    """Piecewise-constant phases: ``levels[i]`` holds on the segment
    between ``breaks[i-1]`` and ``breaks[i]`` (progress fractions)."""

    levels: Tuple[float, ...]
    breaks: Tuple[float, ...]

    def _segment(self, p: float) -> int:
        for i, b in enumerate(self.breaks):
            if p < b:
                return i
        return len(self.levels) - 1

    def value(self, p: float) -> float:
        return self.levels[self._segment(min(max(p, 0.0), 1.0))]

    def peak(self, p0: float) -> float:
        return max(self.levels[self._segment(min(max(p0, 0.0), 1.0)):])


@dataclasses.dataclass(frozen=True)
class BurstyCurve:
    """Low baseline ``lo`` with ``bursts`` seed-placed windows at ``hi``.

    Burst centres are drawn once from ``default_rng(seed)`` — the same
    ``(seed, bursts, width)`` triple replays the same burst placement bit
    for bit, which is what keeps bursty scenarios deterministic.
    """

    lo: float
    hi: float
    centers: Tuple[float, ...]
    width: float

    def value(self, p: float) -> float:
        half = self.width / 2.0
        for c in self.centers:
            if c - half <= p <= c + half:
                return self.hi
        return self.lo

    def peak(self, p0: float) -> float:
        half = self.width / 2.0
        if any(c + half >= p0 for c in self.centers):
            return self.hi
        return self.lo


def _check_frac(name: str, value: float) -> float:
    if not np.isfinite(value) or value < 0:
        raise ValueError(f"usage-curve {name} must be a finite "
                         f"non-negative fraction, got {value}")
    return float(value)


@CURVES.register("constant", doc="flat usage at a fixed fraction of the "
                                 "declared request")
def constant(frac: float = 0.6) -> ConstantCurve:
    """Use ``frac`` of the declared request for the whole lifetime."""
    return ConstantCurve(frac=_check_frac("frac", frac))


@CURVES.register("ramp", doc="linear start→end usage over the lifetime")
def ramp(start: float = 0.9, end: float = 0.3) -> RampCurve:
    """Linear ramp: init-heavy tasks decay (start > end), accumulating
    ones grow (start < end)."""
    return RampCurve(start=_check_frac("start", start),
                     end=_check_frac("end", end))


@CURVES.register("step", doc="piecewise-constant usage phases")
def step(levels: Tuple[float, ...] = (0.9, 0.35),
         breaks: Tuple[float, ...] = (0.4,)) -> StepCurve:
    """Phase model: ``levels[i]`` holds until progress ``breaks[i]``.

    ``breaks`` must be strictly increasing inside (0, 1) with exactly
    ``len(levels) - 1`` entries.
    """
    levels = tuple(_check_frac(f"levels[{i}]", v)
                   for i, v in enumerate(levels))
    breaks = tuple(float(b) for b in breaks)
    if len(breaks) != len(levels) - 1:
        raise ValueError(
            f"step needs len(breaks) == len(levels) - 1, got "
            f"{len(breaks)} breaks for {len(levels)} levels")
    if any(not 0.0 < b < 1.0 for b in breaks) or list(breaks) != \
            sorted(set(breaks)):
        raise ValueError(
            f"step breaks must be strictly increasing in (0, 1), "
            f"got {breaks}")
    return StepCurve(levels=levels, breaks=breaks)


@CURVES.register("bursty", capabilities=("seeded",),
                 doc="low baseline with seed-placed usage bursts")
def bursty(lo: float = 0.3, hi: float = 0.9, bursts: int = 3,
           width: float = 0.08, seed: int = 0) -> BurstyCurve:
    """``bursts`` windows of ``width`` lifetime-fraction at ``hi``,
    centred at seed-drawn points; ``lo`` elsewhere."""
    lo = _check_frac("lo", lo)
    hi = _check_frac("hi", hi)
    if bursts < 1:
        raise ValueError(f"bursty needs bursts >= 1, got {bursts}")
    if not 0.0 < width <= 1.0:
        raise ValueError(f"bursty width must be in (0, 1], got {width}")
    rng = np.random.default_rng(seed)
    centers = tuple(sorted(float(c)
                           for c in rng.uniform(0.0, 1.0, size=bursts)))
    return BurstyCurve(lo=lo, hi=hi, centers=centers, width=float(width))


# ------------------------------------------------------------- sampling
@functools.lru_cache(maxsize=4096)
def _curve_of(name: str, params: Tuple[Tuple[str, object], ...]):
    """Instantiate (and memoize) the curve object for a task's
    ``(usage_curve, usage_params)`` pair."""
    return CURVES.get(name).factory(**dict(params))


def usage_at(task: TaskSpec, p: float) -> Tuple[float, float]:
    """(cpu, mem) the task actually uses at lifetime progress ``p``."""
    curve = _curve_of(task.usage_curve, task.usage_params)
    f = max(curve.value(p), 0.0)
    return f * task.cpu, f * task.mem


def peak_usage(task: TaskSpec, p0: float) -> Tuple[float, float]:
    """(cpu, mem) peak usage over the task's remaining lifetime
    ``[p0, 1]`` — the controller's safe-shrink target."""
    curve = _curve_of(task.usage_curve, task.usage_params)
    f = max(curve.peak(p0), 0.0)
    return f * task.cpu, f * task.mem


# ------------------------------------------------------------ attaching
def _task_seed(seed: int, index: int) -> int:
    # Distinct, deterministic per-task streams from one scenario seed.
    return (seed * 100_003 + index * 7919) & 0x7FFFFFFF


def attach_usage(spec: WorkflowSpec, curve: str,
                 params: Optional[Mapping[str, object]] = None,
                 seed: int = 0) -> WorkflowSpec:
    """Return a copy of ``spec`` whose tasks carry the usage curve.

    Virtual tasks (zero declared cpu *and* mem — DAG glue) are left
    untouched.  For ``seeded`` curves (e.g. ``bursty``) each task gets a
    distinct deterministic seed derived from ``seed`` and its position,
    unless the caller pinned ``seed`` in ``params`` explicitly.
    """
    entry = CURVES.get(curve)
    base = dict(params or {})
    # Validate eagerly: a typo'd parameter should fail at scenario build
    # time with the factory's own error, not mid-simulation.
    try:
        inspect.signature(entry.factory).bind(**base)
    except TypeError as exc:
        raise ValueError(
            f"usage curve {curve!r} rejects params {base}: {exc}") from None
    tasks = {}
    for index, (tid, task) in enumerate(spec.tasks.items()):
        if task.cpu == 0 and task.mem == 0:
            tasks[tid] = task
            continue
        p = dict(base)
        if entry.supports("seeded"):
            p.setdefault("seed", _task_seed(seed, index))
        tasks[tid] = dataclasses.replace(
            task, usage_curve=curve,
            usage_params=tuple(sorted(p.items())))
    return dataclasses.replace(spec, tasks=tasks)
