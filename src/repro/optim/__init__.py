from repro.optim.adafactor import Adafactor, AdafactorState
from repro.optim.adamw import AdamW, AdamWState, global_norm


def make_optimizer(name: str, **kwargs):
    if name == "adamw":
        return AdamW(**kwargs)
    if name == "adafactor":
        return Adafactor(**kwargs)
    raise ValueError(f"unknown optimizer {name!r}")


__all__ = ["AdamW", "AdamWState", "Adafactor", "AdafactorState",
           "global_norm", "make_optimizer"]
