"""Adafactor (Shazeer & Stern, 2018) — factored second moments.

Default optimizer for the 100B+ configs (llama3-405b, jamba-398b): the
second-moment estimate for a [m, n] matrix costs m+n instead of m·n, so
optimizer state for 405B params drops from ~3.2 TB (Adam fp32) to ~0.8 TB
params+state — the difference between fitting and not fitting a single
v5e pod (DESIGN §hardware-adaptation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdafactorState(NamedTuple):
    step: jax.Array
    # per-leaf: dict with either {"vr","vc"} (factored) or {"v"} (full)
    stats: Any


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128


@dataclasses.dataclass(frozen=True)
class Adafactor:
    learning_rate: float = 1e-2
    decay_offset: float = 0.8  # beta2_t = 1 - step^-decay_offset
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    warmup_steps: int = 0

    def init(self, params: Params) -> AdafactorState:
        def leaf_state(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        stats = jax.tree.map(leaf_state, params)
        return AdafactorState(step=jnp.zeros((), jnp.int32), stats=stats)

    def update(self, grads: Params, state: AdafactorState, params: Params
               ) -> Tuple[Params, AdafactorState]:
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay_offset)
        lr = jnp.asarray(self.learning_rate, jnp.float32)
        if self.warmup_steps > 0:
            lr = lr * jnp.minimum(1.0, t / self.warmup_steps)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                rden = jnp.mean(vr, axis=-1, keepdims=True)
                u = g * jax.lax.rsqrt(vr / rden)[..., None] \
                    * jax.lax.rsqrt(vc)[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            # update clipping (RMS of update <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            new_p = p - lr * u
            if self.weight_decay:
                new_p = new_p - lr * self.weight_decay * p
            return new_p.astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.stats)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_stats = treedef.unflatten([o[1] for o in out])
        return new_params, AdafactorState(step=step, stats=new_stats)
