"""AdamW with decoupled weight decay and global-norm clipping (pure JAX)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params  # first moment
    nu: Params  # second moment


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    # lr schedule hooks: linear warmup then cosine decay to lr_min.
    warmup_steps: int = 0
    total_steps: int = 0
    lr_min_ratio: float = 0.1

    def init(self, params: Params) -> AdamWState:
        zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=zeros(params), nu=zeros(params))

    def schedule(self, step: jax.Array) -> jax.Array:
        lr = jnp.asarray(self.learning_rate, jnp.float32)
        if self.warmup_steps > 0:
            warm = jnp.minimum(1.0, (step + 1) / self.warmup_steps)
            lr = lr * warm
        if self.total_steps > 0:
            frac = jnp.clip(
                (step - self.warmup_steps)
                / max(1, self.total_steps - self.warmup_steps), 0.0, 1.0)
            cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
            lr = lr * (self.lr_min_ratio + (1 - self.lr_min_ratio) * cos)
        return lr

    def update(self, grads: Params, state: AdamWState, params: Params
               ) -> Tuple[Params, AdamWState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.schedule(state.step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return p - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                             + self.weight_decay * p)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))
