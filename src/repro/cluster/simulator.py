"""Discrete-event K8s-cluster model.

Stands in for the paper's 6-node testbed (one Master + six 8-core/16 GB
workers, §6.1.1).  The simulator tracks nodes, pods and phases with the
same semantics the ARAS algorithms assume:

* a pod's *quota* (allocated cpu/mem) counts against its node while the pod
  is Pending or Running (Alg. 2 line 8);
* Succeeded / Failed / OOMKilled pods stop consuming but linger until the
  Task Container Cleaner deletes them (paper §4.2), matching the deletion
  latency visible in Fig. 9;
* ``snapshot()`` is the Informer analogue — a cached, consistent view that
  the Resource Discovery reads instead of hitting the API server.

State is struct-of-arrays and **incremental**: node accounting and the
float32 residual cache are mutated in place on ``bind``/``finish``, and
pods live in slot arrays with a free list, so ``snapshot()`` is a flat
array copy instead of a per-call Python rebuild and ``residual_view()``
costs nothing.  ``residual_view`` hands
the allocator the exact float32 arrays the fused burst kernel carries in
its scan, which is what makes batched and per-task decisions bit-for-bit
identical: both see residuals produced by the same sequence of float32
debits.  Pod capacity grows in powers of two so Informer consumers keep
stable JIT shapes (free slots are ``active=False`` and numerically inert).

Invariant (checked): at every instant, Σ quotas of consuming pods on a
node ≤ the node's allocatable capacity.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List

import numpy as np

from repro.cluster.federation import FederatedLayout
from repro.core.types import Allocation, ClusterSnapshot, PodPhase, Resources, TaskSpec


@dataclasses.dataclass
class Pod:
    uid: int
    task: TaskSpec
    quota: Resources
    node: int
    phase: PodPhase = PodPhase.PENDING
    t_created: float = 0.0
    t_started: float = 0.0
    t_finished: float = 0.0
    workflow_id: str = ""
    slot: int = -1  # row in the pod arrays
    resized: bool = False  # quota changed in place after admission (ARC-V)


class ClusterSim:
    """Mutable cluster state + capacity accounting.

    ``num_clusters > 1`` runs the simulator in multi-cluster (federated)
    mode: the node table is partitioned into contiguous, as-even-as-
    possible cluster ranges (global node ids are unchanged — cluster *k*
    owns ``cluster_slices[k]``).  All accounting stays global and
    incremental; the sharded views hand the allocator per-cluster slices
    of the same live arrays, so single- and multi-cluster mode see
    residuals produced by the identical sequence of float32 debits.
    """

    def __init__(self, num_nodes: int, node_cpu: float, node_mem: float,
                 num_clusters: int = 1):
        # The partition rule (and its validation) is owned by
        # FederatedLayout.split — one source of truth for the simulator,
        # the allocator tiles and the global_nodes index mapping.
        self._layout = FederatedLayout.split(num_nodes, num_clusters)
        self.num_nodes = num_nodes
        self.num_clusters = num_clusters
        self.cluster_node_counts = self._layout.node_counts
        # Node accounting: float64 is authoritative (overcommit guard,
        # utilization); the float32 mirror feeds the JAX allocator.
        self._alloc_cpu = np.full((num_nodes,), node_cpu, np.float64)
        self._alloc_mem = np.full((num_nodes,), node_mem, np.float64)
        self._used_cpu = np.zeros((num_nodes,), np.float64)
        self._used_mem = np.zeros((num_nodes,), np.float64)
        # O(1) cluster-wide accounting for utilization sampling (the
        # engine samples on every bind/finish — summing [m] arrays there
        # dominated large-cluster benchmarks).
        self._alloc_cpu_total = float(self._alloc_cpu.sum())
        self._alloc_mem_total = float(self._alloc_mem.sum())
        self._used_cpu_total = 0.0
        self._used_mem_total = 0.0
        self._res_cpu32 = np.full((num_nodes,), node_cpu, np.float32)
        self._res_mem32 = np.full((num_nodes,), node_mem, np.float32)
        self._alloc_cpu32 = self._alloc_cpu.astype(np.float32)
        self._alloc_mem32 = self._alloc_mem.astype(np.float32)
        # Pod registry: dict for object access + slot arrays for the
        # Informer view, mutated on bind/finish/delete.
        self.pods: Dict[int, Pod] = {}
        self._uid = itertools.count()
        self._free_slots: List[int] = []
        self._capacity = 0
        self._pod_node = np.zeros((0,), np.int32)
        self._pod_cpu = np.zeros((0,), np.float32)
        self._pod_mem = np.zeros((0,), np.float32)
        self._pod_active = np.zeros((0,), bool)
        # Dirty-node journal for the device-resident allocator state:
        # when tracking is on, every bind/finish records the touched node
        # so the engine can scatter just those rows into the device tiles
        # instead of re-staging all [m] residuals per dispatch.
        self._track_dirty = False
        self._dirty: List[int] = []
        # Cordon/offline mask (fault injection): an offline node's float32
        # residual mirrors are zeroed — the allocator sees no capacity —
        # and its capacity leaves the O(1) utilization totals, while the
        # float64 books stay untouched so recovery is an exact resync.
        # The counter keeps the hot-path guard in bind() a no-op when no
        # chaos is configured.
        self._offline = np.zeros((num_nodes,), bool)
        self._num_offline = 0

    # ------------------------------------------------------------- plumbing
    def _grow(self) -> None:
        new_cap = max(1, self._capacity * 2)
        self._free_slots.extend(range(self._capacity, new_cap))
        for name in ("_pod_node", "_pod_cpu", "_pod_mem", "_pod_active"):
            old = getattr(self, name)
            grown = np.zeros((new_cap,), old.dtype)
            grown[: self._capacity] = old
            setattr(self, name, grown)
        self._capacity = new_cap

    # ------------------------------------------------------------- pod ops
    # The allocator decides against the float32 mirror, whose rounding can
    # sit a few ULPs above the float64 books; quotas within this slack are
    # admitted (the books may then exceed capacity by up to the epsilon)
    # instead of crashing the run, while genuine overcommits (a real
    # allocator bug) still raise.  0.5 millicores/MiB is far above float32
    # noise and far below any real request.
    _OVERCOMMIT_EPS = 0.5

    def bind(self, task: TaskSpec, alloc: Allocation, now: float,
             workflow_id: str = "") -> Pod:
        """Create a pod with the allocated quota on the chosen node."""
        i = alloc.node
        if self._num_offline and self._offline[i]:
            raise RuntimeError(
                f"bind on offline node {i}: the allocator placed "
                f"quota=({alloc.cpu}, {alloc.mem}) on a cordoned node "
                f"whose residuals should read zero"
            )
        if (self._used_cpu[i] + alloc.cpu
                > self._alloc_cpu[i] + self._OVERCOMMIT_EPS
                or self._used_mem[i] + alloc.mem
                > self._alloc_mem[i] + self._OVERCOMMIT_EPS):
            raise RuntimeError(
                f"overcommit on node {i}: "
                f"used=({self._used_cpu[i]}, {self._used_mem[i]}) "
                f"quota=({alloc.cpu}, {alloc.mem}) "
                f"cap=({self._alloc_cpu[i]}, {self._alloc_mem[i]})"
            )
        self._used_cpu[i] += alloc.cpu
        self._used_mem[i] += alloc.mem
        self._used_cpu_total += alloc.cpu
        self._used_mem_total += alloc.mem
        self._res_cpu32[i] -= np.float32(alloc.cpu)
        self._res_mem32[i] -= np.float32(alloc.mem)
        if self._track_dirty:
            self._dirty.append(i)
        if not self._free_slots:
            self._grow()
        slot = self._free_slots.pop()
        self._pod_node[slot] = i
        self._pod_cpu[slot] = alloc.cpu
        self._pod_mem[slot] = alloc.mem
        self._pod_active[slot] = True
        pod = Pod(
            uid=next(self._uid), task=task, quota=Resources(alloc.cpu, alloc.mem),
            node=i, phase=PodPhase.RUNNING, t_created=now, t_started=now,
            workflow_id=workflow_id, slot=slot,
        )
        self.pods[pod.uid] = pod
        return pod

    def finish(self, uid: int, now: float, phase: PodPhase) -> Pod:
        """Transition a Running pod to a terminal phase, releasing quota."""
        pod = self.pods[uid]
        assert pod.phase == PodPhase.RUNNING, pod
        i = pod.node
        self._used_cpu[i] -= pod.quota.cpu
        self._used_mem[i] -= pod.quota.mem
        self._used_cpu_total -= pod.quota.cpu
        self._used_mem_total -= pod.quota.mem
        # In-place resizes update the books by quota *deltas*, which
        # cannot cancel bit-exactly against the final quota subtraction;
        # snap the ±ulp residue left when a node empties (never triggered
        # by the exact bind/finish pairs of a resize-free run).
        if -1e-6 < self._used_cpu[i] < 0.0:
            self._used_cpu_total -= self._used_cpu[i]
            self._used_cpu[i] = 0.0
        if -1e-6 < self._used_mem[i] < 0.0:
            self._used_mem_total -= self._used_mem[i]
            self._used_mem[i] = 0.0
        assert self._used_cpu[i] >= 0 and self._used_mem[i] >= 0, (i, pod)
        # Resync the float32 mirror from the float64 books on every
        # release: per-op rounding then cannot accumulate across pod
        # lifetimes, keeping the allocator's view within ULPs of truth.
        # Deterministic, and identical for batched and per-task modes
        # (releases only ever happen between bursts).
        self._res_cpu32[i] = np.float32(self._alloc_cpu[i] - self._used_cpu[i])
        self._res_mem32[i] = np.float32(self._alloc_mem[i] - self._used_mem[i])
        if self._track_dirty:
            self._dirty.append(i)
        self._pod_active[pod.slot] = False
        pod.phase = phase
        pod.t_finished = now
        return pod

    def resize(self, uid: int, new_cpu: float, new_mem: float) -> Resources:
        """In-place vertical resize of a Running pod's quota (ARC-V).

        Adjusts the float64 books and O(1) totals by the quota delta,
        resyncs the node's float32 residual mirror from the books (the
        same release-time rule as :meth:`finish`, so per-op rounding
        cannot accumulate across repeated resizes), journals the node
        dirty — a resize rides the identical scatter path into
        device-resident allocator state as any bind/finish — and updates
        the pod slot arrays so Informer consumers see the new quota.

        Grows are bounded by the node's allocatable capacity (same
        ``_OVERCOMMIT_EPS`` slack as :meth:`bind`); shrinks may go to
        zero but not negative.  Returns the previous quota.
        """
        pod = self.pods[uid]
        assert pod.phase == PodPhase.RUNNING, pod
        if new_cpu < 0 or new_mem < 0:
            raise RuntimeError(
                f"resize of pod {uid} to negative quota "
                f"({new_cpu}, {new_mem})")
        # Quotas live on the float32 lattice, like every allocator grant:
        # the pod slot arrays are float32, and the invariant cross-check
        # sums them against the float64 books.
        new_cpu = float(np.float32(new_cpu))
        new_mem = float(np.float32(new_mem))
        i = pod.node
        d_cpu = new_cpu - pod.quota.cpu
        d_mem = new_mem - pod.quota.mem
        if (self._used_cpu[i] + d_cpu
                > self._alloc_cpu[i] + self._OVERCOMMIT_EPS
                or self._used_mem[i] + d_mem
                > self._alloc_mem[i] + self._OVERCOMMIT_EPS):
            raise RuntimeError(
                f"resize overcommit on node {i}: "
                f"used=({self._used_cpu[i]}, {self._used_mem[i]}) "
                f"new quota=({new_cpu}, {new_mem}) "
                f"cap=({self._alloc_cpu[i]}, {self._alloc_mem[i]})"
            )
        self._used_cpu[i] += d_cpu
        self._used_mem[i] += d_mem
        self._used_cpu_total += d_cpu
        self._used_mem_total += d_mem
        self._res_cpu32[i] = np.float32(
            self._alloc_cpu[i] - self._used_cpu[i])
        self._res_mem32[i] = np.float32(
            self._alloc_mem[i] - self._used_mem[i])
        if self._track_dirty:
            self._dirty.append(i)
        self._pod_cpu[pod.slot] = new_cpu
        self._pod_mem[pod.slot] = new_mem
        old = pod.quota
        pod.quota = Resources(new_cpu, new_mem)
        pod.resized = True
        return old

    def node_headroom(self, node: int) -> Resources:
        """Unused allocatable capacity on a node, from the float64 books.

        The vertical controller's grow budget; an offline node reports
        zero (nothing may grow into cordoned capacity).
        """
        if self._offline[node]:
            return Resources(0.0, 0.0)
        return Resources(
            float(self._alloc_cpu[node] - self._used_cpu[node]),
            float(self._alloc_mem[node] - self._used_mem[node]),
        )

    def delete(self, uid: int) -> None:
        """Task Container Cleaner: remove terminal pods from the registry."""
        pod = self.pods.pop(uid)
        assert not pod.phase.consumes_resources, pod
        self._pod_cpu[pod.slot] = 0.0
        self._pod_mem[pod.slot] = 0.0
        self._free_slots.append(pod.slot)

    # ------------------------------------------------------------ fault ops
    def set_node_down(self, node: int, now: float):
        """Take a node offline (injected fault / cordon).

        Every Running pod on the node terminates ``FAILED`` (registry
        insertion order — deterministic), then the node's float32
        residual mirrors are zeroed and journaled dirty so the capacity
        loss rides the same scatter path into device-resident allocator
        state as any bind.  The float64 books are untouched — recovery
        (:meth:`set_node_up`) is an exact resync, not a replay.

        Returns the displaced pods (post-``finish``), or ``None`` if the
        node was already offline (idempotent no-op).
        """
        if self._offline[node]:
            return None
        displaced = [pod for pod in self.pods.values()
                     if pod.node == node and pod.phase is PodPhase.RUNNING]
        # Finish first: each finish resyncs the residual mirror from the
        # books, so the zeroing below must come after.
        for pod in displaced:
            self.finish(pod.uid, now, PodPhase.FAILED)
        self._offline[node] = True
        self._num_offline += 1
        self._res_cpu32[node] = np.float32(0.0)
        self._res_mem32[node] = np.float32(0.0)
        if self._track_dirty:
            self._dirty.append(node)
        self._alloc_cpu_total -= float(self._alloc_cpu[node])
        self._alloc_mem_total -= float(self._alloc_mem[node])
        return displaced

    def set_node_up(self, node: int) -> bool:
        """Bring an offline node back (recovery half of a flap).

        Resyncs the float32 residual mirrors from the float64 books
        (nothing ran while offline, so that is the full allocatable
        capacity), journals the node dirty, and restores its capacity to
        the utilization totals.  Returns ``False`` if the node was not
        offline (idempotent no-op).
        """
        if not self._offline[node]:
            return False
        self._offline[node] = False
        self._num_offline -= 1
        self._res_cpu32[node] = np.float32(
            self._alloc_cpu[node] - self._used_cpu[node])
        self._res_mem32[node] = np.float32(
            self._alloc_mem[node] - self._used_mem[node])
        if self._track_dirty:
            self._dirty.append(node)
        self._alloc_cpu_total += float(self._alloc_cpu[node])
        self._alloc_mem_total += float(self._alloc_mem[node])
        return True

    @property
    def offline_nodes(self):
        """Sorted global ids of currently-offline nodes."""
        return [int(n) for n in np.flatnonzero(self._offline)]

    # --------------------------------------------------------- dirty nodes
    def track_dirty(self, on: bool = True) -> None:
        """Start (or stop) journaling nodes whose residuals change.

        The engine turns this on when it maintains device-resident
        allocator state; ``delete`` never touches residuals, so only
        ``bind``/``finish`` record entries.
        """
        self._track_dirty = on
        self._dirty.clear()

    def drain_dirty(self):
        """Unique dirty node ids + their current float32 residuals.

        Returns ``(nodes, res_cpu, res_mem)`` — copies, safe to hold
        across further mutation — and clears the journal.  The residual
        values are read from the authoritative mirror at drain time, so
        scattering them into device tiles reproduces ``residual_view``
        exactly for those rows.
        """
        if not self._dirty:
            return (np.zeros((0,), np.int64), np.zeros((0,), np.float32),
                    np.zeros((0,), np.float32))
        nodes = np.unique(np.asarray(self._dirty, np.int64))
        self._dirty.clear()
        return (nodes, self._res_cpu32[nodes].copy(),
                self._res_mem32[nodes].copy())

    # ----------------------------------------------------------- informer
    @property
    def cluster_slices(self):
        """Per-cluster ``slice`` into the global node arrays."""
        return tuple(
            slice(off, off + m)
            for off, m in zip(self._layout.offsets,
                              self._layout.node_counts)
        )

    def cluster_of(self, node: int) -> int:
        """The cluster owning a global node id."""
        for k, (off, m) in enumerate(zip(self._layout.offsets,
                                         self._layout.node_counts)):
            if off <= node < off + m:
                return k
        raise IndexError(node)

    def residual_view_sharded(self):
        """Per-cluster float32 residual views — the federated layout.

        One ``(cpu, mem)`` pair of live array views per cluster (treat as
        read-only), slicing the same incrementally-maintained arrays
        ``residual_view`` returns; zero-copy.
        """
        return tuple(
            (self._res_cpu32[s], self._res_mem32[s])
            for s in self.cluster_slices
        )

    def capacity_view_sharded(self):
        """Per-cluster float32 allocatable-capacity views (read-only)."""
        return tuple(
            (self._alloc_cpu32[s], self._alloc_mem32[s])
            for s in self.cluster_slices
        )

    def residual_view(self):
        """Float32 per-node residuals — the allocator's Monitor input.

        These are the live incrementally-maintained arrays (treat as
        read-only); identical to what Alg. 2 would recompute, without the
        O(pods) pass.
        """
        return self._res_cpu32, self._res_mem32

    def capacity_view(self):
        """Float32 per-node allocatable capacity (read-only).

        Feeds capacity-normalized placement scoring (the ``balanced``
        policy) without a snapshot copy.
        """
        return self._alloc_cpu32, self._alloc_mem32

    def snapshot(self) -> ClusterSnapshot:
        """Informer-style struct-of-arrays view for the JAX algorithms.

        A consistent point-in-time copy (later ``bind``/``finish`` calls
        do not mutate it), as callers of an Informer cache expect.  Pod
        arrays are capacity-sized (stable JIT shapes); free slots are
        ``active=False`` with zero quota, so Alg. 2 sees the same totals.
        The engine's hot path uses ``residual_view`` instead and never
        pays this copy.
        """
        return ClusterSnapshot(
            allocatable_cpu=self._alloc_cpu32,
            allocatable_mem=self._alloc_mem32,
            pod_node=self._pod_node.copy(),
            pod_cpu=self._pod_cpu.copy(),
            pod_mem=self._pod_mem.copy(),
            pod_active=self._pod_active.copy(),
        )

    # ------------------------------------------------------------- metrics
    def utilization(self) -> Resources:
        """Fraction of allocatable capacity currently held by quotas.

        O(1): reads the incrementally-maintained cluster totals instead of
        re-summing the node arrays (this runs on every bind/finish).
        Offline nodes' capacity is excluded; a fully-offline cluster
        reports zero utilization rather than dividing by zero.
        """
        if self._alloc_cpu_total <= 0.0 or self._alloc_mem_total <= 0.0:
            return Resources(0.0, 0.0)
        return Resources(
            self._used_cpu_total / self._alloc_cpu_total,
            self._used_mem_total / self._alloc_mem_total,
        )

    def check_invariants(self) -> None:
        assert (self._used_cpu >= 0).all() and (self._used_mem >= 0).all(), \
            (self._used_cpu, self._used_mem)
        eps = self._OVERCOMMIT_EPS
        assert (self._used_cpu <= self._alloc_cpu + eps).all(), self._used_cpu
        assert (self._used_mem <= self._alloc_mem + eps).all(), self._used_mem
        # cross-check node accounting against the pod slot arrays
        active = self._pod_active
        cpu = np.zeros((self.num_nodes,), np.float64)
        mem = np.zeros((self.num_nodes,), np.float64)
        np.add.at(cpu, self._pod_node[active], self._pod_cpu[active])
        np.add.at(mem, self._pod_node[active], self._pod_mem[active])
        assert np.abs(cpu - self._used_cpu).max(initial=0.0) < 1e-3, \
            (cpu, self._used_cpu)
        assert np.abs(mem - self._used_mem).max(initial=0.0) < 1e-3, \
            (mem, self._used_mem)
        # the O(1) cluster totals must track the per-node books; capacity
        # totals count online nodes only
        online = ~self._offline
        assert abs(self._used_cpu_total - self._used_cpu.sum()) < 1e-3
        assert abs(self._used_mem_total - self._used_mem.sum()) < 1e-3
        assert abs(self._alloc_cpu_total - self._alloc_cpu[online].sum()) \
            < 1e-3
        assert abs(self._alloc_mem_total - self._alloc_mem[online].sum()) \
            < 1e-3
        # offline nodes hold no consuming pods and read zero residuals
        if self._num_offline:
            assert (self._used_cpu[self._offline] == 0.0).all()
            assert (self._res_cpu32[self._offline] == 0.0).all()
            assert (self._res_mem32[self._offline] == 0.0).all()
        # the float32 residual caches must track the float64 books
        # (offline nodes are pinned to zero by construction, so the drift
        # check covers online nodes only)
        for res32, alloc, used in (
            (self._res_cpu32, self._alloc_cpu, self._used_cpu),
            (self._res_mem32, self._alloc_mem, self._used_mem),
        ):
            drift = np.abs(res32.astype(np.float64) - (alloc - used))[online]
            assert drift.max(initial=0.0) < 1.0, drift
