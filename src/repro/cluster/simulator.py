"""Discrete-event K8s-cluster model.

Stands in for the paper's 6-node testbed (one Master + six 8-core/16 GB
workers, §6.1.1).  The simulator tracks nodes, pods and phases with the
same semantics the ARAS algorithms assume:

* a pod's *quota* (allocated cpu/mem) counts against its node while the pod
  is Pending or Running (Alg. 2 line 8);
* Succeeded / Failed / OOMKilled pods stop consuming but linger until the
  Task Container Cleaner deletes them (paper §4.2), matching the deletion
  latency visible in Fig. 9;
* ``snapshot()`` is the Informer analogue — a cached, consistent view that
  the Resource Discovery reads instead of hitting the API server.

Invariant (checked): at every instant, Σ quotas of consuming pods on a
node ≤ the node's allocatable capacity.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import numpy as np

from repro.core.types import Allocation, ClusterSnapshot, PodPhase, Resources, TaskSpec


@dataclasses.dataclass
class Node:
    index: int
    allocatable: Resources
    used: Resources = dataclasses.field(default_factory=lambda: Resources(0.0, 0.0))

    @property
    def residual(self) -> Resources:
        return self.allocatable - self.used


@dataclasses.dataclass
class Pod:
    uid: int
    task: TaskSpec
    quota: Resources
    node: int
    phase: PodPhase = PodPhase.PENDING
    t_created: float = 0.0
    t_started: float = 0.0
    t_finished: float = 0.0
    workflow_id: str = ""


class ClusterSim:
    """Mutable cluster state + capacity accounting."""

    def __init__(self, num_nodes: int, node_cpu: float, node_mem: float):
        self.nodes: List[Node] = [
            Node(i, Resources(node_cpu, node_mem)) for i in range(num_nodes)
        ]
        self.pods: Dict[int, Pod] = {}
        self._uid = itertools.count()

    # ------------------------------------------------------------- pod ops
    def bind(self, task: TaskSpec, alloc: Allocation, now: float,
             workflow_id: str = "") -> Pod:
        """Create a pod with the allocated quota on the chosen node."""
        node = self.nodes[alloc.node]
        quota = Resources(alloc.cpu, alloc.mem)
        if not (quota + node.used).fits_in(node.allocatable):
            raise RuntimeError(
                f"overcommit on node {node.index}: used={node.used} "
                f"quota={quota} cap={node.allocatable}"
            )
        node.used = node.used + quota
        pod = Pod(
            uid=next(self._uid), task=task, quota=quota, node=alloc.node,
            phase=PodPhase.RUNNING, t_created=now, t_started=now,
            workflow_id=workflow_id,
        )
        self.pods[pod.uid] = pod
        return pod

    def finish(self, uid: int, now: float, phase: PodPhase) -> Pod:
        """Transition a Running pod to a terminal phase, releasing quota."""
        pod = self.pods[uid]
        assert pod.phase == PodPhase.RUNNING, pod
        node = self.nodes[pod.node]
        node.used = node.used - pod.quota
        assert node.used.nonneg(), (node, pod)
        pod.phase = phase
        pod.t_finished = now
        return pod

    def delete(self, uid: int) -> None:
        """Task Container Cleaner: remove terminal pods from the registry."""
        pod = self.pods.pop(uid)
        assert not pod.phase.consumes_resources, pod

    # ----------------------------------------------------------- informer
    def snapshot(self) -> ClusterSnapshot:
        """Informer-style struct-of-arrays view for the JAX algorithms."""
        pods = list(self.pods.values())
        return ClusterSnapshot(
            allocatable_cpu=np.array(
                [n.allocatable.cpu for n in self.nodes], np.float32
            ),
            allocatable_mem=np.array(
                [n.allocatable.mem for n in self.nodes], np.float32
            ),
            pod_node=np.array([p.node for p in pods], np.int32),
            pod_cpu=np.array([p.quota.cpu for p in pods], np.float32),
            pod_mem=np.array([p.quota.mem for p in pods], np.float32),
            pod_active=np.array(
                [p.phase.consumes_resources for p in pods], bool
            ),
        )

    # ------------------------------------------------------------- metrics
    def utilization(self) -> Resources:
        """Fraction of allocatable capacity currently held by quotas."""
        cap_cpu = sum(n.allocatable.cpu for n in self.nodes)
        cap_mem = sum(n.allocatable.mem for n in self.nodes)
        used_cpu = sum(n.used.cpu for n in self.nodes)
        used_mem = sum(n.used.mem for n in self.nodes)
        return Resources(used_cpu / cap_cpu, used_mem / cap_mem)

    def check_invariants(self) -> None:
        for n in self.nodes:
            assert n.used.nonneg(), n
            assert n.used.fits_in(n.allocatable), n
        # cross-check node accounting against the pod registry
        for n in self.nodes:
            cpu = sum(
                p.quota.cpu for p in self.pods.values()
                if p.node == n.index and p.phase.consumes_resources
            )
            assert abs(cpu - n.used.cpu) < 1e-3, (n, cpu)
