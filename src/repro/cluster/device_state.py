"""Device-resident incremental residual state for the burst allocator.

Every dispatch used to rebuild the full ``[nb, LANE]`` residual and
capacity tile tables from the engine's host float32 caches
(``pad_tiles_federated``): four O(nodes) host→device transfers plus an
O(nodes) pad/gather/reduce per burst, even when the burst touched a
handful of rows.  :class:`DeviceResidualState` keeps the tiles, the
per-block sums and (implicitly, via ``totals_from_block_sums``) the
``[K]`` shard totals resident on device across dispatches and applies
bind/complete deltas as **dirty-tile scatter updates**: a single jitted
``apply`` that touches only the affected 128-wide tiles.

Parity is by construction, not by approximation:

* Updates are *scatter-set*, never device-side arithmetic: the values
  written are read from the engine's authoritative host float32 caches
  at flush time, so after every ``apply_updates`` the device tiles are
  element-for-element the tiles ``pad_tiles_federated`` would rebuild
  from those caches.
* Block sums are re-derived only for dirty blocks, with the same masked
  128-lane row reduction ``tile_block_sums`` uses on the re-pad path;
  equal tile contents therefore give bitwise-equal block sums, and the
  totals both paths feed the sequential core are bitwise-equal too
  (``tests/test_incremental_state.py`` holds the whole pipeline to it).
* The state is functional: ``apply_updates`` returns a new value while
  the old tiles stay alive — a dispatch already issued against the old
  tiles keeps computing against them (JAX arrays are immutable), which
  is what lets the engine double-buffer: fold host events and flush
  deltas while the previous fused dispatch is still in flight.

Scatter index buckets are padded to powers of two (pad indices point one
past the end and are dropped by the scatter), so JIT caches stay warm as
dirty-set sizes vary.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import federation
from repro.cluster.federation import LANE, FederatedLayout


# Scatter buckets are floored at 8 so the expensive fused decision jit
# (repro.core.allocator._state_step, which inlines apply_packed) does not
# recompile across the tiny dirty-set sizes a streaming engine produces.
_MIN_BUCKET = 8


def _pow2(n: int) -> int:
    n = max(n, _MIN_BUCKET)
    return 1 << (n - 1).bit_length()


def pack_update_segment(nodes: np.ndarray, res_cpu: np.ndarray,
                        res_mem: np.ndarray,
                        layout: Optional[FederatedLayout],
                        nb: int):
    """Stage one dirty-set update as a single flat float32 segment.

    Layout: ``n_idx`` padded flat tile positions and ``n_blk`` padded
    dirty block ids, both int32 travelling as raw float32 bits
    (bitcast-exact), followed by ``[2, n_idx]`` cpu/mem residual values —
    one host→device copy instead of four.  Pad positions point one past
    the end (dropped by the scatter); returns ``(seg, n_idx, n_blk)``.
    ``nodes`` may be empty: the segment is then pure padding and the
    apply is a no-op.
    """
    nodes = np.asarray(nodes)
    flat = federation.flat_positions(nodes, layout)
    blocks = np.unique(flat // LANE)
    n_idx = _pow2(flat.shape[0])
    n_blk = _pow2(blocks.shape[0])
    ints = np.empty((n_idx + n_blk,), np.int32)
    ints[: flat.shape[0]] = flat
    ints[flat.shape[0]: n_idx] = nb * LANE
    ints[n_idx: n_idx + blocks.shape[0]] = blocks
    ints[n_idx + blocks.shape[0]:] = nb
    seg = np.zeros((n_idx + n_blk + 2 * n_idx,), np.float32)
    seg[: n_idx + n_blk] = ints.view(np.float32)
    seg[n_idx + n_blk: n_idx + n_blk + nodes.shape[0]] = res_cpu
    seg[2 * n_idx + n_blk: 2 * n_idx + n_blk + nodes.shape[0]] = res_mem
    return seg, n_idx, n_blk


def apply_packed(rc2, rm2, bsum_c, bsum_m, mask2, seg, n_idx: int,
                 n_blk: int):
    """Scatter dirty node values into the tiles, re-sum dirty blocks.

    Traceable (jit-inlinable) form over a :func:`pack_update_segment`
    buffer — the fused streaming dispatch inlines it ahead of the
    decision so one jit call both maintains and consumes the state.
    Duplicate indices carry identical values (deduped host-side, read
    from the same cache), so scatter order cannot matter.
    """
    ints = jax.lax.bitcast_convert_type(seg[: n_idx + n_blk], jnp.int32)
    idx, blk = ints[:n_idx], ints[n_idx:]
    vals = seg[n_idx + n_blk:].reshape(2, n_idx)
    val_c, val_m = vals[0], vals[1]
    nb, lane = rc2.shape
    rc2 = rc2.reshape(-1).at[idx].set(val_c, mode="drop").reshape(nb, lane)
    rm2 = rm2.reshape(-1).at[idx].set(val_m, mode="drop").reshape(nb, lane)
    safe = jnp.clip(blk, 0, nb - 1)  # gather rows; pad rows land nowhere
    rows_mask = mask2[safe]
    rows_c = jnp.where(rows_mask, rc2[safe], jnp.float32(0.0))
    rows_m = jnp.where(rows_mask, rm2[safe], jnp.float32(0.0))
    bsum_c = bsum_c.at[blk].set(jnp.sum(rows_c, axis=1), mode="drop")
    bsum_m = bsum_m.at[blk].set(jnp.sum(rows_m, axis=1), mode="drop")
    return rc2, rm2, bsum_c, bsum_m


_apply = jax.jit(apply_packed, static_argnames=("n_idx", "n_blk"))


@dataclasses.dataclass(frozen=True)
class DeviceResidualState:
    """Allocator state held on device across dispatches.

    ``rc2/rm2`` are the residual tiles (``res_pad`` in padding lanes),
    ``cc2/cm2`` the static capacity tiles, ``bsum_c/bsum_m`` the masked
    per-block residual sums the carried totals are derived from.
    """

    layout: Optional[FederatedLayout]
    num_nodes: int
    res_pad: float
    rc2: jax.Array  # [nb, LANE] f32 residual cpu tiles
    rm2: jax.Array  # [nb, LANE] f32 residual mem tiles
    cc2: jax.Array  # [nb, LANE] f32 allocatable capacity tiles (static)
    cm2: jax.Array  # [nb, LANE] f32
    mask2: jax.Array  # [nb, LANE] bool, True on real-node lanes
    bsum_c: jax.Array  # [nb] f32 masked per-block residual sums
    bsum_m: jax.Array  # [nb] f32

    @staticmethod
    def create(residual_cpu, residual_mem, cap_cpu, cap_mem,
               layout: Optional[FederatedLayout],
               res_pad: float) -> "DeviceResidualState":
        """Stage the host caches once; afterwards only deltas move."""
        res_c = jnp.asarray(residual_cpu, jnp.float32)
        res_m = jnp.asarray(residual_mem, jnp.float32)
        num_nodes = int(res_c.shape[0])
        rc2 = federation.pad_tiles_federated(res_c, layout, res_pad)
        rm2 = federation.pad_tiles_federated(res_m, layout, res_pad)
        cc2 = federation.pad_tiles_federated(
            jnp.asarray(cap_cpu, jnp.float32), layout, 0.0)
        cm2 = federation.pad_tiles_federated(
            jnp.asarray(cap_mem, jnp.float32), layout, 0.0)
        mask2 = jnp.asarray(federation.tile_mask(num_nodes, layout))
        return DeviceResidualState(
            layout=layout, num_nodes=num_nodes, res_pad=res_pad,
            rc2=rc2, rm2=rm2, cc2=cc2, cm2=cm2, mask2=mask2,
            bsum_c=federation.tile_block_sums(rc2, mask2),
            bsum_m=federation.tile_block_sums(rm2, mask2),
        )

    def apply_updates(self, nodes: np.ndarray, res_cpu: np.ndarray,
                      res_mem: np.ndarray) -> "DeviceResidualState":
        """Scatter the given nodes' current host residuals into the tiles.

        ``nodes`` are unique global node ids; ``res_cpu/res_mem`` their
        authoritative host float32 residuals.  Returns a new state; the
        old one stays valid for any dispatch still in flight.
        """
        nodes = np.asarray(nodes)
        if nodes.size == 0:
            return self
        seg, n_idx, n_blk = pack_update_segment(
            nodes, res_cpu, res_mem, self.layout, int(self.rc2.shape[0]))
        rc2, rm2, bsum_c, bsum_m = _apply(
            self.rc2, self.rm2, self.bsum_c, self.bsum_m, self.mask2,
            jnp.asarray(seg), n_idx=n_idx, n_blk=n_blk,
        )
        return dataclasses.replace(
            self, rc2=rc2, rm2=rm2, bsum_c=bsum_c, bsum_m=bsum_m)
