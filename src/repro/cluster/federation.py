"""Multi-cluster federated allocation layout (beyond-paper scale-out).

The paper evaluates one KubeAdaptor against one cluster; production scale
means a *federation*: K clusters, each a contiguous range of the global
node table, pooled behind one allocator (KubeAdaptor is explicitly a
docking framework for heterogeneous clusters, arXiv:2207.01222).  This
module owns the data layout that makes that federation a pure array
transform of the existing burst pipeline:

* ``FederatedLayout`` — the static shape contract: per-cluster node
  counts, every cluster padded to the same number of ``LANE``-wide
  residual blocks (``nb_per``), so the residual/capacity tiles are
  ``[K · nb_per, LANE]`` with the cluster axis flattened into the block
  axis.  A cluster is then a contiguous block range, per-shard reductions
  are reshapes, and the cross-shard reduce is an argmax over K per-shard
  maxima.
* ``pad_tiles_federated`` — flat ``[m]`` node arrays → federated tiles
  (single-cluster layouts delegate to the legacy ``pad_tiles``, so the
  ``num_clusters=1`` path is bit-for-bit the existing allocator).
* ``shard_totals`` — per-shard residual totals ``[K]``; the sequential
  core debits only the accepting shard's entry (O(1), like the legacy
  scalar totals) and re-derives the federation-wide total by a static
  left-fold, which at K=1 is the identity.
* ``global_nodes`` / ``flat_positions`` — kernel flat node indices ↔
  global node ids (the engine binds pods against the global node table;
  the device-resident state scatters dirty nodes back into the tiles).
* ``tile_mask`` / ``tile_block_sums`` / ``totals_from_block_sums`` — the
  hierarchical totals shared by the full re-pad path and the
  incremental dirty-tile path (``repro.cluster.device_state``): masked
  per-block sums ``[nb]``, then a fixed-order reduce to the legacy
  scalar or per-shard ``[K]`` totals.  Equal tile contents give
  bitwise-equal totals, which is what holds the two paths bit-for-bit.
* ``resolve_mesh`` / ``shard_tiles`` — ``jax.sharding`` placement of the
  tile arrays along a 1-D ``clusters`` device mesh
  (``launch.mesh.make_cluster_mesh``); on a single device the mesh is
  ``None`` and everything stays resident exactly as today (documented
  single-device fallback).

Everything here is shape/static metadata — hashable, so layouts ride
through ``jax.jit`` as static arguments without retraces per burst.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Lane width of the residual tiles ([num_blocks, LANE]).  Canonical here —
# the layout module must import nothing from repro (it sits below both the
# allocator and the kernels in the import graph); the sequential cores
# (``repro.kernels.alloc_scan``) re-export it.
LANE = 128


def pad_tiles(arr: jax.Array, pad_value: float) -> jax.Array:
    """Reshape a flat per-node array to [num_blocks, LANE] tiles."""
    m = arr.shape[0]
    nb = -(-m // LANE)
    return jnp.pad(arr, (0, nb * LANE - m),
                   constant_values=pad_value).reshape(nb, LANE)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class FederatedLayout:
    """Static layout of a K-cluster federation over the global node table.

    ``node_counts[k]`` is cluster *k*'s node count; clusters partition the
    global node table contiguously and in order, so global node ids are
    preserved (the property the cross-shard parity suite leans on: a
    federation that never overflows a shard makes exactly the
    single-cluster decisions).
    """

    node_counts: Tuple[int, ...]

    def __post_init__(self):
        if not self.node_counts or any(m <= 0 for m in self.node_counts):
            raise ValueError(
                f"every cluster needs at least one node: {self.node_counts}"
            )

    @property
    def num_clusters(self) -> int:
        return len(self.node_counts)

    @property
    def num_nodes(self) -> int:
        return sum(self.node_counts)

    @property
    def nb_per(self) -> int:
        """Residual blocks per cluster — every shard padded to the max."""
        return max(_ceil_div(m, LANE) for m in self.node_counts)

    @property
    def num_blocks(self) -> int:
        return self.num_clusters * self.nb_per

    @property
    def offsets(self) -> Tuple[int, ...]:
        """Global node id of each cluster's first node."""
        out, acc = [], 0
        for m in self.node_counts:
            out.append(acc)
            acc += m
        return tuple(out)

    @functools.cached_property
    def node_perm(self) -> np.ndarray:
        """``[K · nb_per · LANE]`` map: padded flat position → global node
        id, ``-1`` for padding lanes."""
        span = self.nb_per * LANE
        perm = np.full((self.num_clusters * span,), -1, np.int32)
        for k, (m, off) in enumerate(zip(self.node_counts, self.offsets)):
            perm[k * span: k * span + m] = np.arange(off, off + m)
        return perm

    @staticmethod
    def single(num_nodes: int) -> "FederatedLayout":
        return FederatedLayout((num_nodes,))

    @staticmethod
    def split(num_nodes: int, num_clusters: int) -> "FederatedLayout":
        """Partition ``num_nodes`` into ``num_clusters`` contiguous,
        as-even-as-possible clusters (first clusters take the remainder)."""
        if not 1 <= num_clusters <= num_nodes:
            raise ValueError(
                f"need 1 <= num_clusters <= num_nodes, got "
                f"{num_clusters} clusters for {num_nodes} nodes"
            )
        base, extra = divmod(num_nodes, num_clusters)
        return FederatedLayout(
            tuple(base + (1 if k < extra else 0)
                  for k in range(num_clusters))
        )


def layout_of(cluster) -> FederatedLayout:
    """The layout of a ``ClusterSim`` (single- or multi-cluster mode)."""
    return FederatedLayout(tuple(cluster.cluster_node_counts))


# ------------------------------------------------------------ tile layout

def pad_tiles_federated(
    arr: jax.Array, layout: Optional[FederatedLayout], pad_value: float
) -> jax.Array:
    """Flat ``[m]`` per-node array → ``[K · nb_per, LANE]`` residual tiles.

    ``layout=None`` (and K=1 layouts, whose permutation is the identity)
    take the legacy ``pad_tiles`` path — bit-for-bit today's tiles.
    """
    if layout is None or layout.num_clusters == 1:
        return pad_tiles(arr, pad_value)
    perm = jnp.asarray(layout.node_perm)
    gathered = jnp.where(perm >= 0, arr[jnp.clip(perm, 0)],
                         jnp.asarray(pad_value, arr.dtype))
    return gathered.reshape(layout.num_blocks, LANE)


def shard_totals(arr: jax.Array, layout: Optional[FederatedLayout]):
    """Residual totals: legacy scalar (``layout=None``) or per-shard [K].

    Per-shard entries are plain slice sums over the contiguous cluster
    ranges; the K=1 vector holds exactly the legacy scalar.
    """
    if layout is None:
        return jnp.sum(arr)
    return jnp.stack([
        jnp.sum(arr[off: off + m])
        for off, m in zip(layout.offsets, layout.node_counts)
    ])


@functools.lru_cache(maxsize=None)
def tile_mask(num_nodes: int, layout: Optional[FederatedLayout]) -> np.ndarray:
    """Bool ``[nb, LANE]``: which tile lanes hold real nodes.

    The incremental-state path and the full re-pad path both derive their
    block sums from this one mask, so padding lanes contribute exactly
    ``0.0`` to every reduction in both.  Cached per (size, layout) — the
    mask is static shape metadata, like the layout itself.
    """
    if layout is None or layout.num_clusters == 1:
        nb = _ceil_div(num_nodes, LANE)
        mask = np.zeros((nb * LANE,), bool)
        mask[:num_nodes] = True
        return mask.reshape(nb, LANE)
    return (layout.node_perm >= 0).reshape(layout.num_blocks, LANE)


def tile_block_sums(tiles: jax.Array, mask2) -> jax.Array:
    """Per-block masked sums ``[nb]`` of residual tiles.

    The single reduction shape both totals paths share: the re-pad path
    computes it from freshly padded tiles, the incremental path re-sums
    only dirty blocks — equal tile contents therefore give bitwise-equal
    block sums, and (via :func:`totals_from_block_sums`) bitwise-equal
    carried totals.
    """
    return jnp.sum(jnp.where(mask2, tiles, jnp.float32(0.0)), axis=1)


def totals_from_block_sums(
    bsum: jax.Array, layout: Optional[FederatedLayout]
) -> jax.Array:
    """Residual totals from block sums: legacy scalar or per-shard [K].

    Replaces the flat ``[m]`` reduction of :func:`shard_totals` on the
    burst path so the totals can be re-derived from device-resident
    block sums without ever re-staging the flat node arrays.
    """
    if layout is None:
        return jnp.sum(bsum)
    return jnp.sum(bsum.reshape(layout.num_clusters, layout.nb_per), axis=1)


def flat_positions(
    nodes: np.ndarray, layout: Optional[FederatedLayout]
) -> np.ndarray:
    """Global node ids → padded flat tile positions (host-side).

    The inverse of :func:`global_nodes`, used to target dirty-node
    scatter updates at the device-resident tiles.
    """
    nodes = np.asarray(nodes, np.int64)
    if layout is None or layout.num_clusters == 1:
        return nodes
    offs = np.asarray(layout.offsets, np.int64)
    k = np.searchsorted(offs, nodes, side="right") - 1
    return k * (layout.nb_per * LANE) + (nodes - offs[k])


def global_nodes(
    nodes: np.ndarray, layout: Optional[FederatedLayout]
) -> np.ndarray:
    """Kernel flat node indices → global node ids (``-1`` passes through).

    Host-side, applied once per burst after the single device sync.
    """
    if layout is None or layout.num_clusters == 1:
        return nodes
    nodes = np.asarray(nodes)
    span = layout.nb_per * LANE
    k = np.clip(nodes // span, 0, layout.num_clusters - 1)
    local = nodes - k * span
    offs = np.asarray(layout.offsets, nodes.dtype)
    return np.where(nodes < 0, nodes, offs[k] + local).astype(nodes.dtype)


# --------------------------------------------------------- device sharding

@functools.lru_cache(maxsize=None)
def _cached_mesh(num_clusters: int):
    from repro.launch.mesh import make_cluster_mesh

    return make_cluster_mesh(num_clusters)


SHARDING_POLICIES = ("auto", "force", "off")


def validate_sharding_policy(policy: str) -> str:
    """Fail loudly on a typo'd policy — the single source of truth for
    the allowed ``cluster_sharding`` values (engine construction and
    mesh resolution both call this)."""
    if policy not in SHARDING_POLICIES:
        raise ValueError(
            f"unknown cluster_sharding policy {policy!r} "
            f"(want one of {SHARDING_POLICIES})"
        )
    return policy


def resolve_mesh(layout: Optional[FederatedLayout], policy: str):
    """The ``clusters`` device mesh for a layout, or ``None``.

    ``policy``: ``"auto"``/``"force"`` shard across devices whenever some
    device count > 1 divides the cluster count; ``"off"`` never shards.
    On a single device this always returns ``None`` — the federated
    arithmetic is unchanged, just unsharded (the documented fallback).
    """
    # Validate before any early return: a typo'd policy must fail even
    # in single-cluster setups, not silently run the legacy path.
    validate_sharding_policy(policy)
    if policy == "off" or layout is None or layout.num_clusters == 1:
        return None
    return _cached_mesh(layout.num_clusters)


def shard_tiles(tiles: jax.Array, mesh) -> jax.Array:
    """Lay residual/capacity tiles out along the ``clusters`` mesh axis.

    The block axis is cluster-major and every shard owns ``nb_per``
    blocks, so partitioning the leading axis puts whole clusters on
    devices.
    """
    if mesh is None:
        return tiles
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.device_put(
        tiles, NamedSharding(mesh, PartitionSpec("clusters", None))
    )
