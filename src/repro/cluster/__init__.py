from repro.cluster.federation import FederatedLayout, layout_of
from repro.cluster.simulator import ClusterSim, Pod

__all__ = ["ClusterSim", "FederatedLayout", "Pod", "layout_of"]
