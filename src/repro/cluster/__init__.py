from repro.cluster.simulator import ClusterSim, Pod

__all__ = ["ClusterSim", "Pod"]
