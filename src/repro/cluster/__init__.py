from repro.cluster.simulator import ClusterSim, Node, Pod

__all__ = ["ClusterSim", "Node", "Pod"]
