"""repro.api — the composable public surface of the reproduction.

Three layers (see the README "Scenario API" section):

* **registries** (:mod:`repro.api.registry`) — pluggable allocators,
  placement policies, sequential-core backends, arrival patterns,
  fault schedules and usage curves, registered by decorator with
  capability flags;
* **typed configs** (:mod:`repro.api.config`) — frozen
  ``ClusterConfig`` / ``AllocatorConfig`` / ``TimingConfig`` /
  ``FaultConfig`` composed into ``EngineConfig``
  (JSON-round-trippable, ``validate()``);
* **scenarios** (:mod:`repro.api.scenario`) — declarative ``Scenario``
  specs, the ``run_scenario()`` runner and its structured ``RunResult``.
"""
from repro.api.config import (
    AllocatorConfig,
    ClusterConfig,
    EngineConfig,
    FaultConfig,
    ForecastConfig,
    TimingConfig,
    VerticalConfig,
)
from repro.api.registry import (
    ALLOCATORS,
    ARRIVALS,
    BACKENDS,
    CURVES,
    FAULTS,
    PLACEMENTS,
    Registry,
    RegistryEntry,
)
from repro.api.scenario import (
    RunResult,
    Scenario,
    grid,
    run_grid,
    run_scenario,
)

__all__ = [
    "ALLOCATORS",
    "ARRIVALS",
    "BACKENDS",
    "CURVES",
    "FAULTS",
    "PLACEMENTS",
    "Registry",
    "RegistryEntry",
    "AllocatorConfig",
    "ClusterConfig",
    "EngineConfig",
    "FaultConfig",
    "ForecastConfig",
    "TimingConfig",
    "VerticalConfig",
    "RunResult",
    "Scenario",
    "grid",
    "run_grid",
    "run_scenario",
]
