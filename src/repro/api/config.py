"""Typed engine configuration: frozen sub-configs composed into EngineConfig.

The flat ~20-field ``EngineConfig`` grew one knob per PR; this module
splits it along the paper's own seams:

* :class:`ClusterConfig` — the testbed (§6.1.1): node count/shapes and
  the federated multi-cluster layout (``num_clusters``, device
  ``sharding``).
* :class:`AllocatorConfig` — the Resource Manager: algorithm (registry
  name), ARAS alpha/beta, placement policy, sequential-core backend and
  the burst-vs-per-task allocation unit.
* :class:`TimingConfig` — the discrete-event delays of Figs. 1/9:
  startup, cleanup, restart, OOM fraction, stress duration multiplier.
* :class:`FaultConfig` — injected chaos (a seed-deterministic
  ``FAULTS`` schedule) plus the graceful-degradation knobs: bounded
  retry budget, exponential backoff, per-workflow deadline.
* :class:`ForecastConfig` — online arrival forecasting
  (``repro.forecast``): the adaptive fold window and the predictive
  ``adaptive_scaling`` allocator's look-ahead knobs.
* :class:`VerticalConfig` — vertical adaptivity (ARC-V,
  ``repro.vertical``): the in-place resize controller's check interval,
  shrink/grow hysteresis margins and the resize-first-on-OOM toggle.

``EngineConfig`` composes the six (plus the ``invariant_checks`` debug
flag), JSON-round-trips via ``to_dict``/``from_dict``, and fails early
with actionable messages via :meth:`EngineConfig.validate`.

Construction is composed-only: the deprecated flat constructor keywords
(``EngineConfig(num_nodes=..., alpha=...)``) were shimmed for one
release, warned for a release, and are now removed — an unknown keyword
is a plain ``TypeError``.  ``evolve()`` remains the blessed spelling for
one-knob tweaks and still accepts both composed fields and the flat
names (``cfg.evolve(allocator="fcfs", num_nodes=64)``).
"""
from __future__ import annotations

import dataclasses
import inspect
import json
import warnings
from typing import Any, Dict, Mapping, Optional

from repro.core.types import DEFAULT_ALPHA, DEFAULT_BETA


def _err(message: str) -> ValueError:
    return ValueError(message)


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """The cluster (federation) under management — paper §6.1.1 testbed."""

    num_nodes: int = 6
    # §6.1.1: 8-core / 16 GB workers; ~15% is system-reserved (kubelet,
    # kube-proxy, KubeAdaptor's own pods), as on the paper's testbed.
    node_cpu: float = 6800.0  # allocatable millicores
    node_mem: float = 13600.0  # allocatable MiB
    # Federated multi-cluster mode (repro.cluster.federation): the node
    # table is partitioned into `num_clusters` contiguous cluster shards,
    # residual tiles go cluster-major with per-shard totals, and accepts
    # debit only the owning shard.  1 = the single-cluster paper setup.
    num_clusters: int = 1
    # Device layout of the cluster shards: "auto" shards the residual
    # tiles across a `clusters` jax.sharding mesh when some device count
    # > 1 divides num_clusters (single device: replicated fallback,
    # arithmetic unchanged); "off" never shards; "force" additionally
    # routes num_clusters=1 through the federated K=1 layout — the
    # bit-for-bit regression lever the cross-shard parity suite pulls.
    sharding: str = "auto"

    def validate(self) -> "ClusterConfig":
        from repro.cluster.federation import (
            SHARDING_POLICIES, FederatedLayout,
        )

        if self.num_nodes < 1:
            raise _err(f"ClusterConfig.num_nodes must be >= 1, "
                       f"got {self.num_nodes}")
        if self.node_cpu <= 0 or self.node_mem <= 0:
            raise _err(
                f"ClusterConfig node shapes must be positive, got "
                f"node_cpu={self.node_cpu}, node_mem={self.node_mem}"
            )
        # One source of truth for the partition rule (raises a
        # num_clusters-naming error on an impossible split).
        FederatedLayout.split(self.num_nodes, self.num_clusters)
        if self.sharding not in SHARDING_POLICIES:
            raise _err(
                f"unknown cluster_sharding policy {self.sharding!r} "
                f"(want one of {SHARDING_POLICIES})"
            )
        if self.sharding == "auto" and self.num_clusters > 1:
            import jax

            from repro.launch.mesh import usable_cluster_devices

            devices = jax.device_count()
            if devices > 1 and usable_cluster_devices(self.num_clusters) <= 1:
                # The runtime falls back to one unsharded device (the
                # documented behaviour), so this is a foot-gun warning,
                # not an error — the config still runs correctly.
                warnings.warn(
                    f"cluster_sharding='auto' with num_clusters="
                    f"{self.num_clusters} cannot use the {devices} "
                    f"available devices (no device split > 1 divides the "
                    f"cluster count) and will run unsharded on one "
                    f"device; pick a num_clusters sharing a factor with "
                    f"the device count to enable device sharding, or "
                    f"set sharding='off' to silence this",
                    RuntimeWarning, stacklevel=2,
                )
        return self


@dataclasses.dataclass(frozen=True)
class AllocatorConfig:
    """The Resource Manager: algorithm + placement + sequential core."""

    algorithm: str = "aras"  # repro.api.registry.ALLOCATORS name
    alpha: float = DEFAULT_ALPHA
    beta: float = DEFAULT_BETA
    # Placement policy inside the fused dispatch (PLACEMENTS registry):
    # worst_fit (seed behaviour) | best_fit | first_fit | balanced
    # (kube-scheduler NodeResourcesFit least-allocated scoring) | any
    # registered third-party policy.
    placement: str = "worst_fit"
    # Sequential-core backend (BACKENDS registry): "auto" picks the
    # Pallas kernel on TPU and the lax.scan reference elsewhere.
    backend: str = "auto"
    # Burst-at-a-time allocation (one fused dispatch per timestamp burst).
    # False replays the same burst one dispatch per row — the bit-for-bit
    # parity reference and the bisecting tool for kernel regressions.
    batch_allocation: bool = True
    # Device-resident incremental allocator state: keep the residual/
    # capacity tiles and block sums on device across bursts and apply
    # bind/complete deltas as dirty-tile scatter updates instead of
    # re-staging all O(nodes) arrays per dispatch (decisions are
    # bit-for-bit identical — tests/test_incremental_state.py).  Takes
    # effect in batched mode without a device mesh; False forces the
    # legacy full re-pad path (the parity reference and bisecting tool).
    incremental_state: bool = True

    def validate(self) -> "AllocatorConfig":
        from repro.api.registry import ALLOCATORS, BACKENDS, PLACEMENTS

        ALLOCATORS.get(self.algorithm)  # raises with registered names
        PLACEMENTS.get(self.placement)
        if self.backend != "auto":
            BACKENDS.get(self.backend)
        if not 0.0 < self.alpha <= 1.0:
            raise _err(
                f"AllocatorConfig.alpha is the single-node saturation "
                f"guard, need 0 < alpha <= 1, got {self.alpha}"
            )
        if self.beta < 0:
            raise _err(f"AllocatorConfig.beta is a memory headroom in "
                       f"MiB, need beta >= 0, got {self.beta}")
        return self


@dataclasses.dataclass(frozen=True)
class TimingConfig:
    """Discrete-event delays of the pod lifecycle (Figs. 1 and 9)."""

    pod_startup_delay: float = 40.0  # schedule + image pull + start
    cleanup_delay: float = 5.0  # Task Container Cleaner latency
    restart_delay: float = 2.0  # OOM watch → regenerate latency
    oom_fraction: float = 0.3  # OOM fires this far into the run
    # §6.1.3: Stress CPU/memory operations last twice the task `duration`,
    # so pod wall time = startup + duration_multiplier · duration.
    duration_multiplier: float = 2.0
    max_time: float = 1e7
    # Windowed event drain ("decide at t+ε"): allocatable events within
    # this many seconds of the head event fold into one fused
    # allocate_batch dispatch, so jittered near-simultaneous arrivals
    # from stochastic injectors batch like the paper's lockstep bursts.
    # 0.0 folds only same-timestamp events — the seed drain, bit for bit.
    batch_window: float = 0.0

    def validate(self) -> "TimingConfig":
        for field in ("pod_startup_delay", "cleanup_delay", "restart_delay",
                      "batch_window"):
            if getattr(self, field) < 0:
                raise _err(f"TimingConfig.{field} is a delay in seconds, "
                           f"need >= 0, got {getattr(self, field)}")
        if not 0.0 <= self.oom_fraction <= 1.0:
            raise _err(f"TimingConfig.oom_fraction must lie in [0, 1], "
                       f"got {self.oom_fraction}")
        if self.duration_multiplier <= 0:
            raise _err(f"TimingConfig.duration_multiplier must be > 0, "
                       f"got {self.duration_multiplier}")
        if self.max_time <= 0:
            raise _err(f"TimingConfig.max_time must be > 0, "
                       f"got {self.max_time}")
        return self


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault injection + graceful degradation (repro.chaos).

    ``schedule`` names a :data:`repro.api.registry.FAULTS` entry whose
    seed-deterministic event list the engine pushes at construction;
    ``params`` are its keyword arguments (the engine supplies
    ``num_nodes``, and ``seed`` defaults to this config's ``seed`` unless
    ``params`` pins one explicitly).  The remaining knobs replace the
    seed engine's infinite-retry semantics with bounded degradation:

    * ``max_retries`` — a task may fail admission at most this many
      times; the next failure terminates its whole workflow as a
      ``FAILED`` outcome (``None`` = unbounded, the legacy behaviour;
      ``0`` = first failure kills).  Bounded retry alone cannot
      terminate a run that never completes anything — the first failure
      parks the task in the pending queue and with no completions no
      RETRY ever fires — so pair it with ``workflow_timeout`` as the
      backstop terminator.
    * ``backoff_base``/``backoff_factor`` — after a failed retry round
      the pending queue is gated for ``base * factor**round`` seconds
      (a scheduled RETRY reopens it); 0.0 disables backoff.
    * ``workflow_timeout`` — each workflow gets a deadline this many
      seconds after injection; an incomplete workflow at its deadline
      terminates ``FAILED`` (``None`` = no deadline).
    """

    schedule: str = "none"  # repro.api.registry.FAULTS name
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    seed: int = 0
    max_retries: Optional[int] = None
    backoff_base: float = 0.0
    backoff_factor: float = 2.0
    workflow_timeout: Optional[float] = None

    def validate(self) -> "FaultConfig":
        from repro.api.registry import FAULTS

        entry = FAULTS.get(self.schedule)  # raises with registered names
        merged = {"seed": self.seed, **dict(self.params)}
        try:
            inspect.signature(entry.factory).bind(num_nodes=1, **merged)
        except TypeError as exc:
            raise _err(
                f"FaultConfig.params do not fit fault schedule "
                f"{self.schedule!r}: {exc} (signature is "
                f"{inspect.signature(entry.factory)})"
            ) from None
        if self.max_retries is not None and self.max_retries < 0:
            raise _err(f"FaultConfig.max_retries must be None (unbounded) "
                       f"or >= 0, got {self.max_retries}")
        if self.backoff_base < 0:
            raise _err(f"FaultConfig.backoff_base is a delay in seconds, "
                       f"need >= 0, got {self.backoff_base}")
        if self.backoff_factor < 1.0:
            raise _err(f"FaultConfig.backoff_factor must be >= 1, "
                       f"got {self.backoff_factor}")
        if self.workflow_timeout is not None and self.workflow_timeout <= 0:
            raise _err(f"FaultConfig.workflow_timeout must be None or > 0, "
                       f"got {self.workflow_timeout}")
        return self


@dataclasses.dataclass(frozen=True)
class ForecastConfig:
    """Online arrival forecasting (repro.forecast) — predictive knobs.

    ``enabled=True`` builds an :class:`repro.forecast.ArrivalForecaster`
    inside the engine: a small in-repo MLP fit online (AdamW) on the
    windowed inter-arrival gaps of the live injection stream.  Two
    consumers read it:

    * the **adaptive fold window** — the engine sizes each drained
      burst's fold deadline from the predicted next inter-arrival gap
      (``window_scale`` × prediction, capped at ``max_window`` seconds)
      instead of the static ``TimingConfig.batch_window``;
    * the **predictive allocator** (``AllocatorConfig.algorithm=
      "adaptive_scaling"``) — burst decisions price a ghost demand
      record carrying the expected load of the next ``horizon`` seconds,
      so ARAS quotas tighten *ahead* of a predicted burst instead of
      reacting to it.

    ``enabled=False`` (default) builds nothing and the engine is
    bit-for-bit the static-window engine.  Until ``min_history`` gaps
    have been observed the forecaster abstains and both consumers fall
    back to the static behaviour, so cold starts degrade gracefully.
    All predictions are seed-deterministic given the arrival sequence.
    """

    enabled: bool = False
    history: int = 64  # ring buffer of recent inter-arrival gaps
    window: int = 8  # feature vector: last `window` gaps
    hidden: int = 16  # MLP hidden width (repro.models.layers.mlp)
    lr: float = 0.05  # online AdamW learning rate
    train_every: int = 1  # one fit step per this many observations
    min_history: int = 12  # observed gaps before predictions are trusted
    window_scale: float = 1.0  # fold window = scale × predicted gap
    max_window: float = 4.0  # cap on the adaptive fold window, seconds
    horizon: float = 60.0  # look-ahead for the ghost demand record, s
    # The ghost record may claim at most this fraction of the cluster's
    # current total residual capacity.  Pre-provisioning *shares*
    # capacity with predicted load; an uncapped ghost under a heavy
    # forecast would price every present task below its acceptance
    # floor and starve admission entirely.
    ghost_cap: float = 0.25
    seed: int = 0  # forecaster parameter init

    def validate(self) -> "ForecastConfig":
        if self.window < 1:
            raise _err(f"ForecastConfig.window must be >= 1, "
                       f"got {self.window}")
        if self.history < self.window + 1:
            raise _err(
                f"ForecastConfig.history must exceed window (need at "
                f"least one training pair), got history={self.history}, "
                f"window={self.window}"
            )
        if self.min_history < self.window + 1:
            raise _err(
                f"ForecastConfig.min_history must be >= window + 1 "
                f"(a prediction needs {self.window + 1} observed gaps), "
                f"got {self.min_history}"
            )
        if self.hidden < 1:
            raise _err(f"ForecastConfig.hidden must be >= 1, "
                       f"got {self.hidden}")
        if self.lr <= 0:
            raise _err(f"ForecastConfig.lr must be > 0, got {self.lr}")
        if self.train_every < 1:
            raise _err(f"ForecastConfig.train_every must be >= 1, "
                       f"got {self.train_every}")
        if self.window_scale <= 0:
            raise _err(f"ForecastConfig.window_scale must be > 0, "
                       f"got {self.window_scale}")
        if self.max_window < 0:
            raise _err(f"ForecastConfig.max_window is a cap in seconds, "
                       f"need >= 0, got {self.max_window}")
        if self.horizon < 0:
            raise _err(f"ForecastConfig.horizon is a look-ahead in "
                       f"seconds, need >= 0, got {self.horizon}")
        if self.ghost_cap < 0:
            raise _err(f"ForecastConfig.ghost_cap is a fraction of the "
                       f"cluster's residual capacity, need >= 0, "
                       f"got {self.ghost_cap}")
        return self


@dataclasses.dataclass(frozen=True)
class VerticalConfig:
    """Vertical adaptivity (ARC-V, ``repro.vertical``) — in-place resize.

    ``enabled=True`` arms a resize controller inside the engine: every
    ``check_interval`` simulated seconds (while a usage-curve pod is
    running) a ``RESIZE`` event fires and the controller compares each
    running pod's projected remaining-lifetime peak usage against its
    admitted quota.  Over-provisioned records **shrink** — the freed
    quota returns to the cluster books through the dirty-node journal
    (so device-resident incremental state stays bit-for-bit with host
    re-pad) and a same-time retry pass offers it to the pending queue —
    and under-provisioned records **grow**, node headroom permitting.

    * ``check_interval`` — seconds between controller sweeps.
    * ``grow_margin`` — headroom kept above the projected peak: the
      controller sizes quotas at ``peak × (1 + grow_margin)``.
    * ``shrink_margin`` — hysteresis band: a pod shrinks only when its
      quota exceeds the sized target by more than this fraction, so
      near-steady usage does not churn resizes every sweep.
    * ``resize_on_oom`` — turn the Fig-9 kill/reallocate path into a
      resize-first policy: an OOM-bound pod whose node has memory
      headroom is grown to its runtime floor in place (no restart, no
      lost progress); kill-and-reallocate remains the fallback when the
      node is full.

    ``enabled=False`` (default) builds nothing: no RESIZE events exist
    and the engine is bit-for-bit today's engine.
    """

    enabled: bool = False
    check_interval: float = 15.0
    shrink_margin: float = 0.15
    grow_margin: float = 0.10
    resize_on_oom: bool = True

    def validate(self) -> "VerticalConfig":
        if self.check_interval <= 0:
            raise _err(f"VerticalConfig.check_interval is a period in "
                       f"seconds, need > 0, got {self.check_interval}")
        if self.shrink_margin < 0:
            raise _err(f"VerticalConfig.shrink_margin is a hysteresis "
                       f"fraction, need >= 0, got {self.shrink_margin}")
        if self.grow_margin < 0:
            raise _err(f"VerticalConfig.grow_margin is a headroom "
                       f"fraction, need >= 0, got {self.grow_margin}")
        return self


# Flat evolve() name -> (sub-config field of EngineConfig, field).
_FLAT_MAP: Dict[str, tuple] = {
    "num_nodes": ("cluster", "num_nodes"),
    "node_cpu": ("cluster", "node_cpu"),
    "node_mem": ("cluster", "node_mem"),
    "num_clusters": ("cluster", "num_clusters"),
    "cluster_sharding": ("cluster", "sharding"),
    "allocator": ("alloc", "algorithm"),
    "alpha": ("alloc", "alpha"),
    "beta": ("alloc", "beta"),
    "placement": ("alloc", "placement"),
    "alloc_backend": ("alloc", "backend"),
    "batch_allocation": ("alloc", "batch_allocation"),
    "incremental_state": ("alloc", "incremental_state"),
    "pod_startup_delay": ("timing", "pod_startup_delay"),
    "cleanup_delay": ("timing", "cleanup_delay"),
    "restart_delay": ("timing", "restart_delay"),
    "oom_fraction": ("timing", "oom_fraction"),
    "duration_multiplier": ("timing", "duration_multiplier"),
    "max_time": ("timing", "max_time"),
    "batch_window": ("timing", "batch_window"),
    "fault_schedule": ("faults", "schedule"),
    "fault_params": ("faults", "params"),
    "fault_seed": ("faults", "seed"),
    "max_retries": ("faults", "max_retries"),
    "backoff_base": ("faults", "backoff_base"),
    "backoff_factor": ("faults", "backoff_factor"),
    "workflow_timeout": ("faults", "workflow_timeout"),
    "forecast": ("forecast", "enabled"),
    "forecast_window": ("forecast", "window"),
    "forecast_horizon": ("forecast", "horizon"),
    "forecast_max_window": ("forecast", "max_window"),
    "forecast_seed": ("forecast", "seed"),
    "vertical": ("vertical", "enabled"),
    "resize_interval": ("vertical", "check_interval"),
    "shrink_margin": ("vertical", "shrink_margin"),
    "grow_margin": ("vertical", "grow_margin"),
    "resize_on_oom": ("vertical", "resize_on_oom"),
}

_SUB_TYPES = {"cluster": ClusterConfig, "alloc": AllocatorConfig,
              "timing": TimingConfig, "faults": FaultConfig,
              "forecast": ForecastConfig, "vertical": VerticalConfig}


def _merge_flat(cluster: ClusterConfig, alloc: AllocatorConfig,
                timing: TimingConfig, faults: FaultConfig,
                forecast: ForecastConfig, vertical: VerticalConfig,
                flat: Dict[str, Any]):
    """Route flat evolve() names into the sub-configs they live in."""
    unknown = sorted(set(flat) - set(_FLAT_MAP))
    if unknown:
        raise TypeError(
            f"EngineConfig.evolve got unexpected keyword argument(s) "
            f"{unknown}; composed fields are cluster/alloc/timing/faults/"
            f"forecast/vertical/invariant_checks, flat field names are "
            f"{sorted(_FLAT_MAP)}"
        )
    parts = {"cluster": cluster, "alloc": alloc, "timing": timing,
             "faults": faults, "forecast": forecast, "vertical": vertical}
    updates: Dict[str, Dict[str, Any]] = {}
    for key, value in flat.items():
        part, field = _FLAT_MAP[key]
        updates.setdefault(part, {})[field] = value
    for part, kwargs in updates.items():
        parts[part] = dataclasses.replace(parts[part], **kwargs)
    return (parts["cluster"], parts["alloc"], parts["timing"],
            parts["faults"], parts["forecast"], parts["vertical"])


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Composed engine configuration (cluster × allocator × timing).

    Construct it composed::

        EngineConfig(cluster=ClusterConfig(num_nodes=64),
                     alloc=AllocatorConfig(algorithm="fcfs"))

    The flat constructor keywords of the pre-Scenario-API surface
    (``EngineConfig(num_nodes=64, allocator="fcfs")``) are gone after
    their one-release deprecation window; flat *names* survive only in
    :meth:`evolve`, the one-knob tweak spelling.
    """

    cluster: ClusterConfig = ClusterConfig()
    alloc: AllocatorConfig = AllocatorConfig()
    timing: TimingConfig = TimingConfig()
    faults: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    forecast: ForecastConfig = dataclasses.field(
        default_factory=ForecastConfig)
    vertical: VerticalConfig = dataclasses.field(
        default_factory=VerticalConfig)
    # Per-event O(nodes+pods) accounting cross-checks; disable for
    # large-scale benchmarking.
    invariant_checks: bool = True

    # ------------------------------------------------------------- updates
    def evolve(self, **updates: Any) -> "EngineConfig":
        """Return a copy with updates applied — composed or flat names.

        Accepts sub-config objects (``cluster=ClusterConfig(...)``),
        whole-field replacements (``invariant_checks=False``) and flat
        field names (``allocator="fcfs"``, ``placement=...``) without
        the constructor's deprecation warning; this is the supported
        spelling for one-knob tweaks.
        """
        cluster = updates.pop("cluster", self.cluster)
        alloc = updates.pop("alloc", self.alloc)
        timing = updates.pop("timing", self.timing)
        faults = updates.pop("faults", self.faults)
        # evolve(forecast=...) / evolve(vertical=...) are overloaded the
        # way the fields read naturally: a sub-config instance replaces
        # the whole sub-config, a bool routes to its ``enabled`` via the
        # flat map.
        forecast = self.forecast
        if isinstance(updates.get("forecast"), ForecastConfig):
            forecast = updates.pop("forecast")
        vertical = self.vertical
        if isinstance(updates.get("vertical"), VerticalConfig):
            vertical = updates.pop("vertical")
        checks = updates.pop("invariant_checks", self.invariant_checks)
        cluster, alloc, timing, faults, forecast, vertical = _merge_flat(
            cluster, alloc, timing, faults, forecast, vertical, updates)
        return EngineConfig(cluster=cluster, alloc=alloc, timing=timing,
                            faults=faults, forecast=forecast,
                            vertical=vertical, invariant_checks=checks)

    # ---------------------------------------------------------- validation
    def validate(self) -> "EngineConfig":
        """Fail early, with actionable messages, on an invalid config."""
        from repro.api.registry import ALLOCATORS

        self.cluster.validate()
        self.alloc.validate()
        self.timing.validate()
        self.faults.validate()
        self.forecast.validate()
        self.vertical.validate()
        if ALLOCATORS.get(self.alloc.algorithm).supports("forecast") \
                and not self.forecast.enabled:
            raise _err(
                f"allocator {self.alloc.algorithm!r} is forecast-driven; "
                f"set forecast=ForecastConfig(enabled=True) (or "
                f"evolve(forecast=True)) to feed it predictions"
            )
        return self

    # --------------------------------------------------------- (de)serial
    def to_dict(self) -> Dict[str, Any]:
        faults = dataclasses.asdict(self.faults)
        faults["params"] = dict(self.faults.params)
        return {
            "cluster": dataclasses.asdict(self.cluster),
            "alloc": dataclasses.asdict(self.alloc),
            "timing": dataclasses.asdict(self.timing),
            "faults": faults,
            "forecast": dataclasses.asdict(self.forecast),
            "vertical": dataclasses.asdict(self.vertical),
            "invariant_checks": self.invariant_checks,
        }

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "EngineConfig":
        unknown = sorted(set(data) - set(_SUB_TYPES) - {"invariant_checks"})
        if unknown:
            raise ValueError(
                f"unknown EngineConfig field(s) {unknown} "
                f"(want cluster/alloc/timing/faults/forecast/vertical/"
                f"invariant_checks; flat fields do not appear in the "
                f"serialized form)"
            )
        kwargs: Dict[str, Any] = {}
        for part, sub_cls in _SUB_TYPES.items():
            if part in data:
                kwargs[part] = sub_cls(**data[part])
        return cls(invariant_checks=data.get("invariant_checks", True),
                   **kwargs)

    def to_json(self, **dumps_kwargs: Any) -> str:
        return json.dumps(self.to_dict(), **dumps_kwargs)

    @classmethod
    def from_json(cls, text: str) -> "EngineConfig":
        return cls.from_dict(json.loads(text))
