"""Declarative experiment scenarios and the unified runner.

A :class:`Scenario` is the paper's experimental unit made declarative
(AHPA, arXiv:2303.03640, makes the same move for autoscaling
comparisons): a workflow set, an arrival pattern (registry name +
parameters) and an engine configuration, JSON-round-trippable so a sweep
is data, not wiring.  :func:`run_scenario` executes one scenario through
the KubeAdaptor engine and returns a structured :class:`RunResult`
carrying the paper's Table-2 / Fig-9 metrics (avg total duration, avg
per-workflow duration, CPU/mem usage rates, per-decision latency).

The paper grid — 2 allocators × 3 arrival patterns — is then one
declarative sweep::

    base = Scenario(workflows=("ligo",))
    results = [run_scenario(s) for s in grid(base,
                                             allocators=("aras", "fcfs"),
                                             arrivals=("constant", "linear",
                                                       "pyramid"))]

``run_scenario`` with a single workflow kind is injection-for-injection
identical to the legacy ``repro.engine.run_experiment`` (same rng
stream, same workflow ids), which ``tests/test_scenario_api.py`` gates.
"""
from __future__ import annotations

import dataclasses
import inspect
import json
import time
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.api.config import EngineConfig
from repro.api.registry import ARRIVALS


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One declarative experiment: workflows × arrival × engine config."""

    name: str = "scenario"
    # Workflow kinds (repro.workflows.dags builders); injections cycle
    # through the set, so a single entry reproduces the paper's
    # one-topology experiments and the full set mixes topologies.
    workflows: Tuple[str, ...] = ("ligo",)
    arrival: str = "constant"  # ARRIVALS registry name
    # Keyword arguments for the arrival builder (e.g. y/bursts/interval).
    arrival_params: Mapping[str, Any] = dataclasses.field(
        default_factory=dict)
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    seed: int = 0
    # Optional task-shape overrides handed to every non-virtual task
    # builder (repro.workflows.spec.make_task kwargs).
    task_kwargs: Optional[Mapping[str, Any]] = None
    # Serving mode: run the arrival schedule through the streaming loop
    # (repro.serving.StreamEngine — just-in-time pump, optional
    # admission control) instead of submitting everything up front.
    # stream_params are StreamEngine keyword arguments (prefetch_chunk,
    # max_pending, overload_policy); the serving telemetry lands on the
    # RunResult (decisions/sec, p50/p99 latency, shed/deferred counts).
    stream: bool = False
    stream_params: Mapping[str, Any] = dataclasses.field(
        default_factory=dict)
    # Usage-curve declarations per workflow kind (ARC-V, repro.vertical):
    # {"montage": "ramp"} or {"montage": {"curve": "ramp", "params":
    # {"start": 0.9, "end": 0.2}}}.  Every injected workflow of that kind
    # gets the curve stamped onto its non-virtual tasks
    # (repro.vertical.attach_usage) with seeds derived from the scenario
    # seed, so actual consumption diverges from the admitted quota — the
    # signal EngineConfig.vertical's resize controller acts on.
    usage_curves: Optional[Mapping[str, Any]] = None

    # --------------------------------------------------------------- seeds
    def _arrival_args(self) -> Dict[str, Any]:
        """``arrival_params`` with the scenario seed wired into stochastic
        patterns (``stochastic`` capability flag): the one scenario
        ``seed`` then drives workflow shapes *and* arrival times, so a
        ``grid(seeds=...)`` sweep replicates the whole experiment.  An
        explicit ``arrival_params["seed"]`` pins the arrivals instead."""
        params = dict(self.arrival_params)
        if ARRIVALS.get(self.arrival).supports("stochastic"):
            params.setdefault("seed", self.seed)
        return params

    # ---------------------------------------------------------- validation
    def validate(self) -> "Scenario":
        from repro.workflows.dags import WORKFLOW_BUILDERS

        if not self.workflows:
            raise ValueError("Scenario.workflows must name at least one "
                             "workflow kind")
        unknown = [w for w in self.workflows if w not in WORKFLOW_BUILDERS]
        if unknown:
            raise ValueError(
                f"unknown workflow kind(s) {unknown} "
                f"(registered: {', '.join(sorted(WORKFLOW_BUILDERS))})"
            )
        entry = ARRIVALS.get(self.arrival)  # raises with registered names
        try:
            # Signature-bind only: validation must not execute the
            # builder (it may be expensive or stateful) — run_scenario
            # builds the pattern exactly once, via pattern().
            inspect.signature(entry.factory).bind(**self._arrival_args())
        except TypeError as exc:
            raise ValueError(
                f"arrival_params {dict(self.arrival_params)} do not fit "
                f"arrival pattern {self.arrival!r}: {exc}"
            ) from exc
        unknown_stream = sorted(
            set(self.stream_params)
            - {"prefetch_chunk", "max_pending", "overload_policy"})
        if unknown_stream:
            raise ValueError(
                f"unknown stream_params {unknown_stream} (StreamEngine "
                f"accepts prefetch_chunk/max_pending/overload_policy)")
        if self.stream_params and not self.stream:
            raise ValueError("stream_params given but stream=False — set "
                             "stream=True to run the serving loop")
        if self.usage_curves:
            from repro.api.registry import CURVES

            bad_kinds = sorted(set(self.usage_curves) - set(self.workflows))
            if bad_kinds:
                raise ValueError(
                    f"usage_curves name workflow kind(s) {bad_kinds} not in "
                    f"Scenario.workflows {list(self.workflows)}")
            for kind in self.usage_curves:
                curve, params = self._curve_spec(kind)
                entry = CURVES.get(curve)  # raises with registered names
                try:
                    inspect.signature(entry.factory).bind(**params)
                except TypeError as exc:
                    raise ValueError(
                        f"usage_curves[{kind!r}] params {params} do not "
                        f"fit curve {curve!r}: {exc}") from None
        self.engine.validate()
        return self

    def _curve_spec(self, kind: str) -> Tuple[str, Dict[str, Any]]:
        """Normalize one ``usage_curves`` entry to (curve, params)."""
        decl = self.usage_curves[kind]
        if isinstance(decl, str):
            return decl, {}
        decl = dict(decl)
        unknown = sorted(set(decl) - {"curve", "params"})
        if unknown or "curve" not in decl:
            raise ValueError(
                f"usage_curves[{kind!r}] must be a curve name or a "
                f"{{'curve': ..., 'params': {{...}}}} mapping, got {decl}")
        return decl["curve"], dict(decl.get("params") or {})

    # ------------------------------------------------------------ behavior
    def pattern(self) -> List[Tuple[float, int]]:
        """The concrete (time, count) burst list of this scenario."""
        return ARRIVALS.get(self.arrival).factory(**self._arrival_args())

    def num_workflows(self) -> int:
        return sum(count for _, count in self.pattern())

    # --------------------------------------------------------- (de)serial
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "workflows": list(self.workflows),
            "arrival": self.arrival,
            "arrival_params": dict(self.arrival_params),
            "engine": self.engine.to_dict(),
            "seed": self.seed,
            "task_kwargs": dict(self.task_kwargs)
            if self.task_kwargs is not None else None,
            "stream": self.stream,
            "stream_params": dict(self.stream_params),
            "usage_curves": ({k: (v if isinstance(v, str) else dict(v))
                              for k, v in self.usage_curves.items()}
                             if self.usage_curves is not None else None),
        }

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "Scenario":
        kwargs = dict(data)
        if "workflows" in kwargs:
            workflows = kwargs["workflows"]
            kwargs["workflows"] = ((workflows,)
                                   if isinstance(workflows, str)
                                   else tuple(workflows))
        if "engine" in kwargs:
            kwargs["engine"] = EngineConfig.from_dict(kwargs["engine"])
        return cls(**kwargs)

    def to_json(self, **dumps_kwargs: Any) -> str:
        return json.dumps(self.to_dict(), **dumps_kwargs)

    @classmethod
    def from_json(cls, text: str) -> "Scenario":
        return cls.from_dict(json.loads(text))


def grid(base: Scenario, *,
         allocators: Tuple[str, ...] = ("aras", "fcfs"),
         arrivals: Tuple[str, ...] = ("constant", "linear", "pyramid"),
         seeds: Optional[Tuple[int, ...]] = None,
         fault_params: Optional[Tuple[Mapping[str, Any], ...]] = None,
         ) -> List[Scenario]:
    """The paper's evaluation grid as a flat list of scenarios.

    Every (allocator, arrival) pair of the sweep becomes one scenario
    derived from ``base`` (name suffixed ``-<allocator>-<arrival>``);
    ``base.arrival_params`` apply to every arrival pattern, so pass only
    parameters the swept patterns share (or none for the paper defaults).

    ``seeds`` adds a replication axis (suffix ``-s<seed>``): each seed
    re-draws the workflow task shapes, and — for arrival patterns
    carrying the ``stochastic`` capability flag (``poisson``,
    ``jittered``) — the arrival timestamps too, since the scenario seed
    feeds the arrival builder unless ``arrival_params`` pins one.

    Forecast-capable allocators (the ``forecast`` capability flag, e.g.
    ``adaptive_scaling``) get ``EngineConfig.forecast`` enabled
    automatically when the base engine leaves it off, so
    ``allocators=("aras", "adaptive_scaling")`` sweeps static-vs-
    predictive without a hand-built engine per cell; an explicit
    ``base.engine.forecast`` is kept as-is for every cell.

    ``fault_params`` adds a chaos axis (suffix ``-f<i>``): each entry is
    a parameter-override mapping merged over the base engine's
    ``FaultConfig.params`` — so recovery-time sweeps are one call::

        grid(base, fault_params=tuple({"recovery_time": r}
                                      for r in (60.0, 120.0, 300.0)))

    with ``base.engine`` carrying ``fault_schedule="node_flap"``.  The
    merged params must fit the schedule's signature; an override that
    does not (e.g. ``recovery_time`` against the default ``none``
    schedule) fails the scenario's ``validate()`` with the signature in
    the message.
    """
    from repro.api.registry import ALLOCATORS

    def _engine_for(algorithm: str,
                    overrides: Optional[Mapping[str, Any]]) -> EngineConfig:
        engine = base.engine.evolve(allocator=algorithm)
        if ALLOCATORS.get(algorithm).supports("forecast") \
                and not engine.forecast.enabled:
            engine = engine.evolve(forecast=True)
        if overrides is not None:
            engine = engine.evolve(fault_params={
                **dict(engine.faults.params), **dict(overrides)})
        return engine

    seed_axis: Tuple[Optional[int], ...] = \
        (None,) if seeds is None else tuple(seeds)
    fault_axis: Tuple[Optional[Mapping[str, Any]], ...] = \
        (None,) if fault_params is None else tuple(fault_params)
    return [
        dataclasses.replace(
            base,
            name=(f"{base.name}-{algorithm}-{arrival}"
                  + ("" if seed is None else f"-s{seed}")
                  + ("" if overrides is None else f"-f{fi}")),
            arrival=arrival,
            engine=_engine_for(algorithm, overrides),
            seed=base.seed if seed is None else seed,
        )
        for algorithm in allocators
        for arrival in arrivals
        for seed in seed_axis
        for fi, overrides in enumerate(fault_axis)
    ]


@dataclasses.dataclass
class RunResult:
    """Structured outcome of one scenario — §6.1.5 metrics + trace.

    The scalar fields are the paper's comparison metrics (Table 2 /
    Fig. 9) and JSON-serialize via :meth:`to_dict`; ``metrics`` keeps the
    full :class:`repro.engine.EngineMetrics` trace (usage series,
    allocation trace, OOM events) for plotting and is deliberately left
    out of the serialized form.
    """

    scenario: Scenario
    avg_total_duration: float  # makespan: Total Duration of All Workflows
    avg_workflow_duration: float
    cpu_usage_rate: float  # time-weighted quota / allocatable
    mem_usage_rate: float
    per_decision_latency_us: float
    num_workflows: int
    num_allocations: int
    num_waits: int
    num_oom_events: int
    num_reallocations: int
    # Dispatch efficiency of the windowed drain (TimingConfig.batch_window):
    # how many device dispatches the allocation path issued and the mean
    # task rows per dispatch — a wider mean burst at fewer dispatches is
    # the win of folding jittered arrivals into one fused MAPE-K cycle.
    num_dispatches: int
    mean_burst_width: float
    sla_violation_rate: float
    wall_time_s: float
    # Fault injection + graceful degradation (EngineConfig.faults):
    # displaced = running pods lost to NODE_DOWN, recovered = displaced
    # tasks that re-bound via HEAL, failed = retry-budget/deadline
    # terminations (FAILED outcomes; failed workflows do not count in
    # num_workflows, which stays completed-only).
    num_displaced: int = 0
    num_recovered: int = 0
    num_failed_tasks: int = 0
    num_failed_workflows: int = 0
    mean_time_to_recovery: float = 0.0
    # Forecast telemetry (EngineConfig.forecast / repro.forecast):
    # arrivals observed, drains sized by a live prediction, the mean
    # adaptive fold window they used, and burst decisions that priced a
    # ghost forecast-demand record (adaptive_scaling allocator).
    forecast_observations: int = 0
    forecast_predictions: int = 0
    mean_forecast_window: float = 0.0
    forecast_ghost_rows: int = 0
    # Vertical adaptivity telemetry (EngineConfig.vertical /
    # repro.vertical): in-place resizes, shrink-reclaimed capacity
    # integrated over the pods' remaining lifetimes (millicore·s /
    # MiB·s), and OOM kills the resize-first policy avoided.
    num_resizes: int = 0
    num_shrinks: int = 0
    num_grows: int = 0
    resizes_avoided_oom: int = 0
    reclaimed_cpu_seconds: float = 0.0
    reclaimed_mem_seconds: float = 0.0
    # Serving telemetry (Scenario.stream=True): StreamStats wired in so
    # grid() sweeps can gate on serving latency, not just makespan.
    decisions_per_sec: float = 0.0
    p50_latency_us: float = 0.0
    p99_latency_us: float = 0.0
    shed_workflows: int = 0
    deferred_workflows: int = 0
    metrics: Any = dataclasses.field(repr=False, compare=False, default=None)

    def to_dict(self) -> Dict[str, Any]:
        out = {
            field.name: getattr(self, field.name)
            for field in dataclasses.fields(self)
            if field.name not in ("scenario", "metrics")
        }
        out["scenario"] = self.scenario.to_dict()
        return out

    def to_json(self, **dumps_kwargs: Any) -> str:
        return json.dumps(self.to_dict(), **dumps_kwargs)


def run_scenario(scenario: Scenario) -> RunResult:
    """Validate, execute and summarize one scenario.

    Workflows are injected with the same rng stream and id scheme as the
    legacy ``run_experiment`` (``<kind>-<index>`` against one
    ``default_rng(seed)``), so a single-kind scenario reproduces it bit
    for bit; multi-kind scenarios cycle the workflow set per injection.
    """
    import numpy as np

    from repro.engine.kubeadaptor import KubeAdaptor
    from repro.workflows.dags import WORKFLOW_BUILDERS

    scenario.validate()
    engine = KubeAdaptor(scenario.engine)
    rng = np.random.default_rng(scenario.seed)
    task_kwargs = dict(scenario.task_kwargs) if scenario.task_kwargs else None
    arrivals = []
    idx = 0
    for t, count in scenario.pattern():
        for _ in range(count):
            kind = scenario.workflows[idx % len(scenario.workflows)]
            spec = WORKFLOW_BUILDERS[kind](f"{kind}-{idx}", rng, task_kwargs)
            if scenario.usage_curves and kind in scenario.usage_curves:
                from repro.vertical import attach_usage

                curve, params = scenario._curve_spec(kind)
                # Per-injection seed: seeded curves (bursty) differ
                # across workflows but replay bit for bit per scenario.
                spec = attach_usage(spec, curve, params,
                                    seed=scenario.seed * 1_000_003 + idx)
            arrivals.append((t, spec))
            idx += 1
    stats = None
    if scenario.stream:
        from repro.serving.stream import StreamEngine

        server = StreamEngine(engine, arrivals,
                              **dict(scenario.stream_params))
        t0 = time.perf_counter()
        stats = server.serve()
        wall = time.perf_counter() - t0
        metrics = stats.metrics
    else:
        for t, spec in arrivals:
            engine.submit(spec, t)
        t0 = time.perf_counter()
        metrics = engine.run()
        wall = time.perf_counter() - t0
    decisions = max(metrics.num_allocations, 1)
    return RunResult(
        scenario=scenario,
        avg_total_duration=metrics.makespan,
        avg_workflow_duration=metrics.avg_workflow_duration,
        cpu_usage_rate=metrics.avg_cpu_usage,
        mem_usage_rate=metrics.avg_mem_usage,
        per_decision_latency_us=1e6 * wall / decisions,
        num_workflows=len(metrics.workflow_durations),
        num_allocations=metrics.num_allocations,
        num_waits=metrics.num_waits,
        num_oom_events=len(metrics.oom_events),
        num_reallocations=len(metrics.realloc_events),
        num_dispatches=metrics.num_dispatches,
        mean_burst_width=metrics.mean_burst_width,
        sla_violation_rate=metrics.sla_violation_rate,
        wall_time_s=wall,
        num_displaced=metrics.num_displaced,
        num_recovered=metrics.num_recovered,
        num_failed_tasks=len(metrics.failed_tasks),
        num_failed_workflows=len(metrics.failed_workflows),
        mean_time_to_recovery=metrics.mean_time_to_recovery,
        forecast_observations=metrics.forecast_observations,
        forecast_predictions=metrics.forecast_predictions,
        mean_forecast_window=metrics.mean_forecast_window,
        forecast_ghost_rows=metrics.forecast_ghost_rows,
        num_resizes=metrics.num_resizes,
        num_shrinks=metrics.num_shrinks,
        num_grows=metrics.num_grows,
        resizes_avoided_oom=metrics.resizes_avoided_oom,
        reclaimed_cpu_seconds=metrics.reclaimed_cpu_seconds,
        reclaimed_mem_seconds=metrics.reclaimed_mem_seconds,
        decisions_per_sec=stats.decisions_per_sec if stats else 0.0,
        p50_latency_us=1e6 * stats.p50_latency_s if stats else 0.0,
        p99_latency_us=1e6 * stats.p99_latency_s if stats else 0.0,
        shed_workflows=stats.shed_workflows if stats else 0,
        deferred_workflows=stats.deferred_workflows if stats else 0,
        metrics=metrics,
    )


def run_grid(scenarios: List[Scenario]) -> List[RunResult]:
    """Run a list of scenarios (e.g. from :func:`grid`), in order."""
    return [run_scenario(s) for s in scenarios]
