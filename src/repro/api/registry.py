"""Decorator-based plugin registries for the composable Scenario API.

The engine's pluggable functional units (KubeAdaptor is explicitly a
docking framework, arXiv:2207.01222) were selected by string-dispatch
``if`` chains spread across ``core/allocator.py``, ``core/placement.py``,
``kernels/alloc_scan/ops.py`` and ``workflows/arrival.py``.  This module
replaces those chains with four registries, so a third-party allocator,
placement policy, sequential-core backend or arrival pattern plugs in
with one decorator and no edits to core files:

    from repro.api.registry import PLACEMENTS

    @PLACEMENTS.register("most_free_mem",
                         doc="max residual memory among fitting nodes")
    def _most_free_mem(res_cpu, res_mem, cpu, mem, cap_cpu, cap_mem):
        return res_mem                       # any jnp expression works

    EngineConfig(alloc=AllocatorConfig(placement="most_free_mem"))

Entries carry **capability flags** — free-form strings the engine and
``validate()`` consult instead of hard-coding per-name behaviour (e.g.
``needs_capacity_view`` makes ``placement_key`` demand per-node
allocatable capacities; ``adaptive_scaling`` tells the engine to hand the
allocator its alpha/beta knobs).

Built-in entries live next to their implementations (the modules named in
``bootstrap_modules``) and are imported lazily on first lookup, so the
registry module itself sits at the bottom of the import graph and never
cycles.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Iterator, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class RegistryEntry:
    """One registered plugin: a factory plus static metadata."""

    name: str
    factory: Callable[..., Any]
    capabilities: frozenset
    doc: str = ""

    def supports(self, capability: str) -> bool:
        return capability in self.capabilities


class Registry:
    """A named collection of :class:`RegistryEntry`.

    ``bootstrap_modules`` are imported (once, lazily) before the first
    lookup so built-in entries registered at those modules' import time
    are always visible, regardless of what the caller imported first.
    """

    def __init__(self, kind: str, *,
                 bootstrap_modules: Tuple[str, ...] = ()):
        self.kind = kind
        self._entries: Dict[str, RegistryEntry] = {}
        self._aliases: Dict[str, str] = {}
        self._bootstrap_modules = tuple(bootstrap_modules)
        self._bootstrapped = not bootstrap_modules

    # ------------------------------------------------------------- plumbing
    def _bootstrap(self) -> None:
        if self._bootstrapped:
            return
        self._bootstrapped = True  # set first: the modules import us back
        try:
            for mod in self._bootstrap_modules:
                importlib.import_module(mod)
        except BaseException:
            # Let the next lookup retry (and re-raise the real import
            # error) instead of reporting a misleading empty registry.
            self._bootstrapped = False
            raise

    # ------------------------------------------------------------ mutation
    def register(self, name: str, *,
                 capabilities: Tuple[str, ...] = (),
                 aliases: Tuple[str, ...] = (),
                 doc: Optional[str] = None,
                 overwrite: bool = False) -> Callable:
        """Decorator: register ``factory`` under ``name`` (+ ``aliases``)."""

        def deco(factory: Callable) -> Callable:
            taken = set(self._entries) | set(self._aliases)
            clashes = ({name, *aliases} & taken) if not overwrite else set()
            if clashes:
                raise ValueError(
                    f"{self.kind} {sorted(clashes)} already registered "
                    f"(pass overwrite=True to replace)"
                )
            if overwrite:
                # Drop any stale alias occupying one of the new names, so
                # the overwriting entry is actually the one resolved.
                for taken_name in {name, *aliases}:
                    self._aliases.pop(taken_name, None)
            summary = doc if doc is not None else \
                (factory.__doc__ or "").strip().split("\n")[0]
            self._entries[name] = RegistryEntry(
                name=name, factory=factory,
                capabilities=frozenset(capabilities), doc=summary,
            )
            for alias in aliases:
                self._aliases[alias] = name
            return factory

        return deco

    def unregister(self, name: str) -> None:
        """Remove an entry and its aliases; given an alias, remove just
        that alias (no-op for unknown names)."""
        if name in self._entries:
            del self._entries[name]
            for alias in [a for a, c in self._aliases.items() if c == name]:
                del self._aliases[alias]
        else:
            self._aliases.pop(name, None)

    # -------------------------------------------------------------- lookup
    def get(self, name: str) -> RegistryEntry:
        """Entry for ``name`` (or an alias); actionable ``ValueError``.

        A canonical entry always wins over an alias of the same name, so
        overwrite-registrations cannot be shadowed by stale aliases.
        """
        self._bootstrap()
        canonical = name if name in self._entries \
            else self._aliases.get(name, name)
        entry = self._entries.get(canonical)
        if entry is None:
            raise ValueError(
                f"unknown {self.kind} {name!r} "
                f"(registered: {', '.join(self.names()) or 'none'})"
            )
        return entry

    def names(self) -> Tuple[str, ...]:
        """Canonical entry names, sorted (aliases not included)."""
        self._bootstrap()
        return tuple(sorted(self._entries))

    def __contains__(self, name: str) -> bool:
        self._bootstrap()
        return name in self._entries or name in self._aliases

    def __iter__(self) -> Iterator[RegistryEntry]:
        self._bootstrap()
        return iter(self._entries[n] for n in self.names())

    def __len__(self) -> int:
        self._bootstrap()
        return len(self._entries)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Registry({self.kind!r}, entries={list(self._entries)})"


# The six engine registries.  Built-ins register at import time of the
# modules that implement them (lazily triggered on first lookup).
ALLOCATORS = Registry(
    "allocator", bootstrap_modules=("repro.core.allocator",))
PLACEMENTS = Registry(
    "placement policy", bootstrap_modules=("repro.core.placement",))
BACKENDS = Registry(
    "alloc backend", bootstrap_modules=("repro.kernels.alloc_scan.ops",))
ARRIVALS = Registry(
    "arrival pattern", bootstrap_modules=("repro.workflows.arrival",))
FAULTS = Registry(
    "fault schedule", bootstrap_modules=("repro.chaos",))
CURVES = Registry(
    "usage curve", bootstrap_modules=("repro.vertical",))
