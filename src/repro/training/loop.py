"""Training loop with checkpoint/restart, failure injection, and MAPE-K
self-healing — the workload-plane mirror of the paper's Fig. 9 behaviour.

The loop is deliberately small: scheduling/queueing of *many* training
jobs belongs to the engine (``repro.engine.mljobs``); this file owns one
job's lifecycle:

    restore-if-possible → step* → periodic async checkpoint → on simulated
    failure: restart from last checkpoint (bit-exact: step-indexed data).

The OOM self-healing path (allocation below the activation-memory floor →
halve microbatch and relaunch) reuses the same restart mechanics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.synthetic import SyntheticDataset
from repro.models.api import ArchModel
from repro.training.train_step import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    # fault injection: raise at this step (once) to exercise restart
    fail_at_step: Optional[int] = None
    grad_accum: int = 1
    seed: int = 0


class SimulatedFailure(RuntimeError):
    pass


def train(
    model: ArchModel,
    optimizer,
    dataset: SyntheticDataset,
    cfg: LoopConfig,
    *,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> TrainState:
    """Run (or resume) one training job to ``total_steps``."""
    ckpt = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep)
    step_fn = jax.jit(make_train_step(model, optimizer,
                                      grad_accum=cfg.grad_accum))

    state = init_train_state(model, optimizer, jax.random.key(cfg.seed))
    restored = ckpt.restore_latest(state)
    if restored is not None:
        _, state = restored

    failed_once = False
    history: List[float] = []
    step = int(state.step)
    while step < cfg.total_steps:
        if cfg.fail_at_step is not None and step == cfg.fail_at_step \
                and not failed_once:
            failed_once = True
            # crash-restart: lose in-memory state, restore from checkpoint
            state = init_train_state(model, optimizer,
                                     jax.random.key(cfg.seed))
            restored = ckpt.restore_latest(state)
            if restored is not None:
                _, state = restored
            step = int(state.step)
            continue
        batch = dataset.batch_at(step)
        state, metrics = step_fn(state, batch)
        step = int(state.step)
        history.append(float(metrics["loss"]))
        if on_metrics and (step % cfg.log_every == 0 or step == 1):
            on_metrics(step, jax.tree.map(float, metrics))
        if step % cfg.checkpoint_every == 0 or step == cfg.total_steps:
            ckpt.save(state, step)
    ckpt.wait()
    train.last_history = history  # exposed for tests/examples
    return state
