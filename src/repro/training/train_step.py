"""Train-step factory: grad accumulation, clipping, optional compression.

The returned step is a pure function suitable for jit/pjit; microbatch
gradient accumulation runs as a ``lax.scan`` so backward reduce-scatters
of microbatch k overlap with the forward of microbatch k+1 under XLA's
latency-hiding scheduler (the §Perf overlap lever).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import ArchModel, Batch
from repro.optim import global_norm

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt_state: Any
    step: jax.Array


def init_train_state(model: ArchModel, optimizer, key: jax.Array
                     ) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(
    model: ArchModel,
    optimizer,
    *,
    grad_accum: int = 1,
    impl: str = "reference",
    compress_grads: Optional[Callable[[Params], Params]] = None,
) -> Callable[[TrainState, Batch], Tuple[TrainState, Dict[str, jax.Array]]]:
    """Build ``train_step(state, batch) -> (state, metrics)``.

    ``grad_accum > 1`` splits the global batch into microbatches along the
    leading axis and accumulates grads in fp32.  ``compress_grads`` (e.g.
    ``repro.parallel.compression.int8_allreduce``) post-processes the
    cross-replica gradient reduction.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch, impl=impl)

    def train_step(state: TrainState, batch: Batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((grad_accum, -1) + x.shape[1:]), b)

            microbatches = micro(batch)

            def accum(carry, mb):
                g_sum, l_sum = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (g_sum, l_sum + l), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss_sum), metrics_all = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), microbatches)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)

        if compress_grads is not None:
            grads = compress_grads(grads)

        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params)
        metrics = dict(metrics)
        metrics["grad_norm"] = global_norm(grads)
        metrics["loss"] = loss
        return TrainState(params=params, opt_state=opt_state,
                          step=state.step + 1), metrics

    return train_step
