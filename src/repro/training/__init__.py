from repro.training.loop import LoopConfig, SimulatedFailure, train
from repro.training.train_step import (
    TrainState,
    init_train_state,
    make_train_step,
)

__all__ = ["LoopConfig", "SimulatedFailure", "train", "TrainState",
           "init_train_state", "make_train_step"]
