"""Model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / VLM / enc-dec LMs;
family-specific blocks are enabled by fields being non-None.  Configs for
the ten assigned architectures live in ``repro.configs.<id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def pad_vocab(vocab_size: int, multiple: int = 256) -> int:
    """Pad the embedding table so every TP degree divides it (MaxText-style)."""
    return ((vocab_size + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    expert_d_ff: int = 0  # per-expert FFN width
    num_shared_experts: int = 0  # DeepSeek-style always-on experts
    capacity_factor: float = 1.25
    every_k_layers: int = 1  # MoE replaces MLP on layers where i % k == k-1
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3
    # "scatter": capacity-slot scatter-add dispatch + gather combine,
    #   O(T·k·D) data movement (production default — see EXPERIMENTS §Perf);
    # "einsum": one-hot [T,E,C] dispatch/combine matmuls, O(T·E·C·D) FLOPs
    #   (kept as the naive reference; what the §Perf baseline measured).
    dispatch_mode: str = "scatter"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention flavour
    attention: str = "full"  # "full" | "swa" | "none" (pure SSM)
    sliding_window: int = 4096  # only for attention == "swa"
    qkv_bias: bool = False  # Qwen2
    rope_theta: float = 500000.0
    use_rope: bool = True  # Whisper uses absolute (sinusoidal) positions
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"

    # --- family blocks
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (Jamba): within each group of `hybrid_group` layers, layer 0 is
    # attention and the rest are Mamba.  None -> not hybrid.
    hybrid_group: Optional[int] = None
    # VLM: every `cross_attn_every`-th layer is a gated cross-attention
    # layer reading the (stubbed) vision embeddings.  None -> not a VLM.
    cross_attn_every: Optional[int] = None
    num_vision_tokens: int = 1601  # stub frontend output length
    # enc-dec (Whisper): `num_layers` decoder layers + this many encoder
    # layers over stubbed frame embeddings.  None -> decoder-only.
    encoder_layers: Optional[int] = None
    num_audio_frames: int = 1500  # stub frontend output length

    # --- moe first-layer override (DeepSeek: dense layer 0)
    first_layer_dense_ff: int = 0  # >0: layer 0 uses a dense MLP this wide

    # --- numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"  # master copy; cast to `dtype` for compute
    remat: bool = True
    # "full": recompute everything in bwd (min memory);
    # "dots": save matmul outputs, recompute elementwise only (≈25% fewer
    #   flops, more live activation memory) — see EXPERIMENTS §Perf iter 5.
    remat_policy: str = "full"
    # scan layer stacks (O(1) HLO). False unrolls — only for the dry-run's
    # FLOP calibration (HLO cost analysis counts while bodies once).
    scan_layers: bool = True
    logits_softcap: float = 0.0
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % self.num_kv_heads != 0:
            raise ValueError("num_heads must be a multiple of num_kv_heads")
        if self.ssm is not None and self.ssm.dt_rank == 0:
            object.__setattr__(
                self, "ssm",
                dataclasses.replace(self.ssm, dt_rank=-(-self.d_model // 16)),
            )

    # ------------------------------------------------------------ derived
    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand if self.ssm else 2) * self.d_model

    @property
    def is_hybrid(self) -> bool:
        return self.hybrid_group is not None

    @property
    def is_ssm_only(self) -> bool:
        return self.ssm is not None and not self.is_hybrid

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers is not None

    @property
    def is_vlm(self) -> bool:
        return self.cross_attn_every is not None

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (spec: SSM/hybrid/SWA only)."""
        return self.is_ssm_only or self.is_hybrid or self.attention == "swa"

    def layer_kind(self, i: int) -> str:
        """'attn' | 'mamba' | 'cross' for layer i (decoder stack)."""
        if self.is_ssm_only:
            return "mamba"
        if self.is_hybrid:
            return "attn" if i % self.hybrid_group == 0 else "mamba"
        if self.is_vlm and (i + 1) % self.cross_attn_every == 0:
            return "cross"
        return "attn"

    def uses_moe(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.first_layer_dense_ff > 0 and i == 0:
            return False
        k = self.moe.every_k_layers
        return i % k == k - 1

    # -------------------------------------------------- parameter counting
    def param_count(self) -> int:
        """Exact parameter count (used for MODEL_FLOPS = 6·N·D)."""
        return _count_params(self)

    def active_param_count(self) -> int:
        """Active-per-token parameters (MoE: top_k + shared experts only)."""
        return _count_params(self, active_only=True)


def _attn_params(cfg: ModelConfig) -> int:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    n = d * h * hd + 2 * d * kv * hd + h * hd * d  # q, k, v, o
    if cfg.qkv_bias:
        n += (h + 2 * kv) * hd
    return n


def _mlp_params(d_model: int, d_ff: int) -> int:
    return 3 * d_model * d_ff  # SwiGLU: gate, up, down


def _mamba_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    di, d = cfg.d_inner, cfg.d_model
    n = d * 2 * di  # in_proj
    n += di * s.d_conv  # depthwise conv
    n += di * (s.dt_rank + 2 * s.d_state)  # x_proj
    n += s.dt_rank * di + di  # dt_proj (+bias)
    n += di * s.d_state + di  # A_log, D
    n += di * d  # out_proj
    return n


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = cfg.padded_vocab * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        total += cfg.padded_vocab * cfg.d_model  # unembedding
    total += cfg.d_model  # final norm

    def moe_layer(moe: MoEConfig) -> int:
        router = cfg.d_model * moe.num_experts
        experts = moe.top_k if active_only else moe.num_experts
        n = router + experts * _mlp_params(cfg.d_model, moe.expert_d_ff)
        n += moe.num_shared_experts * _mlp_params(cfg.d_model, moe.expert_d_ff)
        return n

    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        total += 2 * cfg.d_model  # 2 norms per layer
        if kind == "mamba":
            total += _mamba_params(cfg)
        else:
            total += _attn_params(cfg)
        if kind != "mamba":
            pass
        if cfg.uses_moe(i):
            total += moe_layer(cfg.moe)
        elif cfg.first_layer_dense_ff > 0 and i == 0:
            total += _mlp_params(cfg.d_model, cfg.first_layer_dense_ff)
        else:
            total += _mlp_params(cfg.d_model, cfg.d_ff)

    if cfg.is_encdec:
        # encoder layers: self-attn + MLP;  decoder cross-attn weights.
        enc = cfg.encoder_layers * (
            _attn_params(cfg) + _mlp_params(cfg.d_model, cfg.d_ff)
            + 2 * cfg.d_model
        )
        cross = cfg.num_layers * (_attn_params(cfg) + cfg.d_model)
        total += enc + cross + cfg.d_model
    if cfg.is_vlm:
        pass  # cross layers already counted via layer_kind
    return int(total)
