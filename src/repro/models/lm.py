"""Decoder-only LM — dense (llama/qwen/h2o) and MoE (olmoe/deepseek) families.

Layer stacks are parameter-stacked ([L, ...] leaves) and applied with
``lax.scan`` so the HLO stays O(1) in depth — essential for compiling the
126-layer llama3-405b dry-run quickly.  ``cfg.remat`` wraps the scanned
body in ``jax.checkpoint`` (full recompute policy) for activation memory.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import _remat_policy
from repro.parallel import act_sharding as act
from repro.models.config import ModelConfig

Params = Dict[str, Any]


class DecodeCache(NamedTuple):
    """Per-layer KV cache, parameter-stacked: leaves [L, B, T, KV, Dh]."""

    k: jax.Array
    v: jax.Array
    pos: jax.Array  # [B] next write position (== tokens generated so far)


def _layer_init(cfg: ModelConfig, use_moe: bool):
    def init(key):
        ks = jax.random.split(key, 4)
        p = {
            "ln1": L.init_norm(cfg),
            "attn": L.init_attention(ks[0], cfg),
            "ln2": L.init_norm(cfg),
        }
        if use_moe:
            p["moe"] = L.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff)
        return p

    return init


def _layer_apply(cfg: ModelConfig, p: Params, x: jax.Array,
                 positions: jax.Array, impl: str, use_moe: bool):
    h = x + L.attention(p["attn"], cfg, L.norm(cfg, p["ln1"], x),
                        positions=positions, impl=impl)
    hn = L.norm(cfg, p["ln2"], h)
    if use_moe:
        y, aux = L.moe(p["moe"], cfg, hn)
        aux_vec = jnp.stack([aux.load_balance_loss, aux.router_z_loss,
                             aux.dropped_fraction])
    else:
        y = L.mlp(p["mlp"], hn)
        aux_vec = jnp.zeros((3,), jnp.float32)
    return h + y, aux_vec


class DecoderLM:
    """Uniform decoder stack; DeepSeek's dense layer 0 handled separately."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._moe_stack = cfg.moe is not None
        self._dense_first = cfg.first_layer_dense_ff > 0
        self._n_scanned = cfg.num_layers - (1 if self._dense_first else 0)

    # ------------------------------------------------------------- init
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_emb, k_layers, k_first = jax.random.split(key, 3)
        params: Params = {
            "embedding": L.init_embedding(k_emb, cfg),
            "final_norm": L.init_norm(cfg),
        }
        init_fn = _layer_init(cfg, self._moe_stack)
        params["layers"] = jax.vmap(init_fn)(
            jax.random.split(k_layers, self._n_scanned))
        if self._dense_first:
            ks = jax.random.split(k_first, 2)
            params["first_layer"] = {
                "ln1": L.init_norm(cfg),
                "attn": L.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg),
                "mlp": L.init_mlp(ks[1], cfg.d_model,
                                  cfg.first_layer_dense_ff),
            }
        return params

    # ---------------------------------------------------------- forward
    def forward(self, params: Params, tokens: jax.Array,
                impl: str = "reference") -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """tokens [B,S] -> (logits [B,S,V], aux losses)."""
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = L.embed(params["embedding"], cfg, tokens)

        if self._dense_first:
            x, _ = _layer_apply(cfg, params["first_layer"], x, positions,
                                impl, use_moe=False)

        def body(carry, layer_p):
            x = carry
            x, aux = _layer_apply(cfg, layer_p, x, positions, impl,
                                  use_moe=self._moe_stack)
            return x, aux

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, aux_all = L.scan_or_unroll(body, x, params["layers"], cfg.scan_layers)
        aux_sum = jnp.sum(aux_all, axis=0)

        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x)
        aux = {
            "load_balance_loss": aux_sum[0],
            "router_z_loss": aux_sum[1],
            "dropped_fraction": aux_sum[2] / max(1, self._n_scanned),
        }
        return logits, aux

    # ------------------------------------------------------------ cache
    def cache_len(self, max_len: int) -> int:
        """SWA models keep a ring buffer of `window`, others the full span."""
        cfg = self.cfg
        if cfg.attention == "swa":
            return min(cfg.sliding_window, max_len)
        return max_len

    def init_cache(self, batch: int, max_len: int) -> DecodeCache:
        cfg = self.cfg
        T = self.cache_len(max_len)
        shape = (cfg.num_layers, batch, T, cfg.num_kv_heads, cfg.head_dim)
        dt = jnp.dtype(cfg.dtype)
        return DecodeCache(
            k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
            pos=jnp.zeros((batch,), jnp.int32),
        )

    def _ring_metadata(self, pos: jax.Array, T: int):
        """Absolute positions + validity for (ring or linear) cache slots.

        pos: [B] count of tokens already in the cache.  Linear caches have
        slot j holding position j (valid when j < pos); SWA ring caches
        hold p_j = last position ≡ j (mod W) strictly before `pos`.
        """
        cfg = self.cfg
        B = pos.shape[0]
        j = jnp.arange(T, dtype=jnp.int32)[None, :]
        if cfg.attention == "swa" and T == cfg.sliding_window:
            last = pos[:, None] - 1  # most recent written position
            p = last - jnp.mod(last - j, T)
            valid = p >= 0
            return p, valid
        p = jnp.broadcast_to(j, (B, T))
        return p, j < pos[:, None]

    def decode_step(self, params: Params, tokens: jax.Array,
                    cache: DecodeCache, impl: str = "reference"
                    ) -> Tuple[jax.Array, DecodeCache]:
        """One token per sequence: tokens [B,1] -> logits [B,1,V]."""
        cfg = self.cfg
        B = tokens.shape[0]
        T = cache.k.shape[2]
        pos = cache.pos  # [B]
        x = L.embed(params["embedding"], cfg, tokens)

        slot = jnp.mod(pos, T) if cfg.attention == "swa" else pos
        kv_pos, kv_valid = self._ring_metadata(pos + 1, T)

        def attn_block(p, x, layer_k, layer_v):
            hn = L.norm(cfg, p["ln1"], x)
            q, k, v = L._project_qkv(p["attn"], cfg, hn)
            q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
            k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
            write = lambda buf, val: jax.vmap(
                lambda b, s, w: jax.lax.dynamic_update_slice(b, w, (s, 0, 0))
            )(buf, slot, val)
            layer_k = write(layer_k, k)
            layer_v = write(layer_v, v)
            out = L.sdpa_reference(
                q, layer_k, layer_v, causal=True, q_offset=pos,
                kv_positions=kv_pos, kv_valid=kv_valid,
            )
            out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
            return x + out @ p["attn"]["wo"].astype(x.dtype), layer_k, layer_v

        # dense-first layer (DeepSeek) runs outside the scan
        if self._dense_first:
            p0 = params["first_layer"]
            x, k0, v0 = attn_block(p0, x, cache.k[0], cache.v[0])
            x = x + L.mlp(p0["mlp"], L.norm(cfg, p0["ln2"], x))

        def body(x, scanned):
            layer_p, layer_k, layer_v = scanned
            x, layer_k, layer_v = attn_block(layer_p, x, layer_k, layer_v)
            hn = L.norm(cfg, layer_p["ln2"], x)
            if self._moe_stack:
                y, _ = L.moe(layer_p["moe"], cfg, hn, dropless=True)
            else:
                y = L.mlp(layer_p["mlp"], hn)
            return x + y, (layer_k, layer_v)

        off = 1 if self._dense_first else 0
        x, (new_k, new_v) = L.scan_or_unroll(
            body, x, (params["layers"], cache.k[off:], cache.v[off:]),
            cfg.scan_layers)
        if self._dense_first:
            new_k = jnp.concatenate([k0[None], new_k], axis=0)
            new_v = jnp.concatenate([v0[None], new_v], axis=0)

        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x)
        return logits, DecodeCache(k=new_k, v=new_v, pos=pos + 1)

    def prefill(self, params: Params, tokens: jax.Array, max_len: int,
                impl: str = "reference") -> Tuple[jax.Array, DecodeCache]:
        """Run the full sequence, returning last-position logits + cache."""
        cfg = self.cfg
        B, S = tokens.shape
        cache = self.init_cache(B, max_len)
        T = cache.k.shape[2]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = L.embed(params["embedding"], cfg, tokens)

        def run_layer(p, x):
            hn = L.norm(cfg, p["ln1"], x)
            q, k, v = L._project_qkv(p["attn"], cfg, hn)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            window = cfg.sliding_window if cfg.attention == "swa" else None
            if impl == "pallas":
                from repro.kernels.flash_attention import ops as fa_ops

                out = fa_ops.flash_attention(q, k, v, causal=True,
                                             window=window)
            else:
                out = L.sdpa_reference(q, k, v, causal=True, window=window)
            out = act.constrain_attn_out(out).reshape(B, S, cfg.num_heads * cfg.head_dim)
            return x + out @ p["attn"]["wo"].astype(x.dtype), k, v

        def block(p, x, use_moe):
            x, k, v = run_layer(p, x)
            hn = L.norm(cfg, p["ln2"], x)
            if use_moe:
                y, _ = L.moe(p["moe"], cfg, hn)
            else:
                y = L.mlp(p["mlp"], hn)
            return x + y, k, v

        if self._dense_first:
            x, k0, v0 = block(params["first_layer"], x, use_moe=False)

        def body(x, layer_p):
            x, k, v = block(layer_p, x, use_moe=self._moe_stack)
            return x, (k, v)

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, (k_all, v_all) = L.scan_or_unroll(body, x, params["layers"],
                                             cfg.scan_layers)

        if self._dense_first:
            k_all = jnp.concatenate([k0[None], k_all], axis=0)
            v_all = jnp.concatenate([v0[None], v_all], axis=0)

        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x[:, -1:])
        if cfg.attention == "swa" and T == cfg.sliding_window and S >= T:
            # keep the last W positions, placed at their ring slots
            tail_k, tail_v = k_all[:, :, S - T:], v_all[:, :, S - T:]
            roll = jnp.mod(S - T, T)
            k_ring = jnp.roll(tail_k, roll, axis=2)
            v_ring = jnp.roll(tail_v, roll, axis=2)
            cache = DecodeCache(k=k_ring, v=v_ring,
                                pos=jnp.full((B,), S, jnp.int32))
        else:
            pad = T - S
            if pad < 0:
                raise ValueError("prefill longer than cache")
            k_all = jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v_all = jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache = DecodeCache(k=k_all.astype(cache.k.dtype),
                                v=v_all.astype(cache.v.dtype),
                                pos=jnp.full((B,), S, jnp.int32))
        return logits, cache
