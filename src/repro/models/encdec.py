"""Whisper-backbone encoder-decoder (audio family).

The conv frontend is stubbed per the assignment: the model consumes
precomputed frame embeddings [B, F, D] (``input_specs()`` supplies them).
Encoder: bidirectional self-attention with sinusoidal positions.
Decoder: causal self-attention + cross-attention to the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import _remat_policy
from repro.parallel import act_sharding as act
from repro.models.config import ModelConfig

Params = Dict[str, Any]


class EncDecCache(NamedTuple):
    k: jax.Array  # [L, B, T, KV, Dh]  decoder self-attn
    v: jax.Array
    xk: jax.Array  # [L, B, F, KV, Dh]  static cross-attn (encoder output)
    xv: jax.Array
    pos: jax.Array  # [B]


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.is_encdec
        self.cfg = cfg

    # ------------------------------------------------------------- init
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": L.init_norm(cfg),
                "attn": L.init_attention(k1, cfg),
                "ln2": L.init_norm(cfg),
                "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff),
            }

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "ln1": L.init_norm(cfg),
                "attn": L.init_attention(k1, cfg),
                "lnx": L.init_norm(cfg),
                "xattn": L.init_attention(k2, cfg),
                "ln2": L.init_norm(cfg),
                "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff),
            }

        return {
            "embedding": L.init_embedding(ks[0], cfg),
            "enc_layers": jax.vmap(enc_layer)(
                jax.random.split(ks[1], cfg.encoder_layers)),
            "enc_norm": L.init_norm(cfg),
            "dec_layers": jax.vmap(dec_layer)(
                jax.random.split(ks[2], cfg.num_layers)),
            "final_norm": L.init_norm(cfg),
        }

    # ------------------------------------------------------------ encode
    def encode(self, params: Params, frames: jax.Array,
               impl: str = "reference") -> jax.Array:
        """frames [B, F, D] (stub frontend output) -> encoder states."""
        cfg = self.cfg
        B, F, _ = frames.shape
        pos = L.sinusoidal_positions(jnp.arange(F), cfg.d_model)
        x = frames.astype(jnp.dtype(cfg.dtype)) + pos.astype(jnp.dtype(cfg.dtype))

        def body(x, p):
            x = x + L.attention(p["attn"], cfg, L.norm(cfg, p["ln1"], x),
                                causal=False, impl=impl)
            x = x + L.mlp(p["mlp"], L.norm(cfg, p["ln2"], x))
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, _ = L.scan_or_unroll(body, x, params["enc_layers"], cfg.scan_layers)
        return L.norm(cfg, params["enc_norm"], x)

    # ------------------------------------------------------------ decode
    def forward(self, params: Params, tokens: jax.Array, frames: jax.Array,
                impl: str = "reference") -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        B, S = tokens.shape
        enc = self.encode(params, frames, impl)
        x = L.embed(params["embedding"], cfg, tokens)
        x = x + L.sinusoidal_positions(
            jnp.arange(S), cfg.d_model).astype(x.dtype)

        def body(x, p):
            x = x + L.attention(p["attn"], cfg, L.norm(cfg, p["ln1"], x),
                                causal=True, impl=impl)
            x = x + L.attention(p["xattn"], cfg, L.norm(cfg, p["lnx"], x),
                                kv_input=enc, impl=impl)
            x = x + L.mlp(p["mlp"], L.norm(cfg, p["ln2"], x))
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, _ = L.scan_or_unroll(body, x, params["dec_layers"], cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        return L.unembed(params["embedding"], cfg, x), {}

    # ------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int) -> EncDecCache:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        Ld, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        F = cfg.num_audio_frames
        return EncDecCache(
            k=jnp.zeros((Ld, batch, max_len, kv, hd), dt),
            v=jnp.zeros((Ld, batch, max_len, kv, hd), dt),
            xk=jnp.zeros((Ld, batch, F, kv, hd), dt),
            xv=jnp.zeros((Ld, batch, F, kv, hd), dt),
            pos=jnp.zeros((batch,), jnp.int32),
        )

    def prefill(self, params: Params, tokens: jax.Array, frames: jax.Array,
                max_len: int, impl: str = "reference"
                ) -> Tuple[jax.Array, EncDecCache]:
        cfg = self.cfg
        B, S = tokens.shape
        enc = self.encode(params, frames, impl)
        x = L.embed(params["embedding"], cfg, tokens)
        x = x + L.sinusoidal_positions(
            jnp.arange(S), cfg.d_model).astype(x.dtype)
        pad = max_len - S

        def body(x, p):
            hn = L.norm(cfg, p["ln1"], x)
            q, k, v = L._project_qkv(p["attn"], cfg, hn)
            out = L.sdpa_reference(q, k, v, causal=True)
            out = act.constrain_attn_out(out).reshape(B, S, cfg.num_heads * cfg.head_dim)
            x = x + out @ p["attn"]["wo"].astype(x.dtype)
            _, xk, xv = L._project_qkv(p["xattn"], cfg,
                                       L.norm(cfg, p["lnx"], x), kv_input=enc)
            x = x + L.attention(p["xattn"], cfg, L.norm(cfg, p["lnx"], x),
                                kv_input=enc, impl=impl)
            x = x + L.mlp(p["mlp"], L.norm(cfg, p["ln2"], x))
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return x, (kp, vp, xk, xv)

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, (k, v, xk, xv) = L.scan_or_unroll(body, x, params["dec_layers"],
                                             cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x[:, -1:])
        dt = jnp.dtype(cfg.dtype)
        return logits, EncDecCache(
            k=k.astype(dt), v=v.astype(dt), xk=xk.astype(dt),
            xv=xv.astype(dt), pos=jnp.full((B,), S, jnp.int32))

    def decode_step(self, params: Params, tokens: jax.Array,
                    cache: EncDecCache, impl: str = "reference"
                    ) -> Tuple[jax.Array, EncDecCache]:
        cfg = self.cfg
        B = tokens.shape[0]
        T = cache.k.shape[2]
        pos = cache.pos
        x = L.embed(params["embedding"], cfg, tokens)
        x = x + L.sinusoidal_positions(
            pos[:, None], cfg.d_model).astype(x.dtype)
        j = jnp.arange(T, dtype=jnp.int32)[None, :]
        kv_valid = j < (pos + 1)[:, None]

        def body(x, scanned):
            p, lk, lv, lxk, lxv = scanned
            hn = L.norm(cfg, p["ln1"], x)
            q, k, v = L._project_qkv(p["attn"], cfg, hn)
            write = lambda buf, val: jax.vmap(
                lambda b, s, w: jax.lax.dynamic_update_slice(b, w, (s, 0, 0))
            )(buf, pos, val)
            lk, lv = write(lk, k), write(lv, v)
            out = L.sdpa_reference(q, lk, lv, causal=True, q_offset=pos,
                                   kv_valid=kv_valid)
            out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
            x = x + out @ p["attn"]["wo"].astype(x.dtype)
            hn = L.norm(cfg, p["lnx"], x)
            q = (hn @ p["xattn"]["wq"].astype(x.dtype)).reshape(
                B, 1, cfg.num_heads, cfg.head_dim)
            out = L.sdpa_reference(q, lxk, lxv, causal=False)
            out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
            x = x + out @ p["xattn"]["wo"].astype(x.dtype)
            x = x + L.mlp(p["mlp"], L.norm(cfg, p["ln2"], x))
            return x, (lk, lv)

        x, (k, v) = L.scan_or_unroll(
            body, x, (params["dec_layers"], cache.k, cache.v,
                      cache.xk, cache.xv),
            cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x)
        return logits, EncDecCache(k=k, v=v, xk=cache.xk, xv=cache.xv,
                                   pos=pos + 1)
