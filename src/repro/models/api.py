"""Unified model API over all architecture families.

``build_model(cfg)`` returns an ``ArchModel`` exposing family-independent
entry points used by the trainer, server, dry-run and tests:

    init(key) -> params
    loss(params, batch) -> (scalar, metrics)          # train step core
    forward(params, batch) -> (logits, aux)
    prefill(params, batch, max_len) -> (logits, cache)
    decode_step(params, tokens, cache) -> (logits, cache)

Batches are dicts: tokens/labels always; ``vision_embeds`` (VLM) or
``frames`` (audio) when the modality stub applies.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.hybrid import HybridLM
from repro.models.lm import DecoderLM
from repro.models.ssm_lm import MambaLM
from repro.models.vlm import VisionLM

Params = Dict[str, Any]
Batch = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def model_family(cfg: ModelConfig) -> str:
    if cfg.is_encdec:
        return "encdec"
    if cfg.is_vlm:
        return "vlm"
    if cfg.is_hybrid:
        return "hybrid"
    if cfg.is_ssm_only:
        return "mamba"
    return "decoder"


class ArchModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.family = model_family(cfg)
        impl_cls = {
            "decoder": DecoderLM, "mamba": MambaLM, "hybrid": HybridLM,
            "vlm": VisionLM, "encdec": EncDecLM,
        }[self.family]
        self.m = impl_cls(cfg)

    # ------------------------------------------------------------ passes
    def init(self, key: jax.Array) -> Params:
        return self.m.init(key)

    def _extra(self, batch: Batch):
        if self.family == "vlm":
            return (batch["vision_embeds"],)
        if self.family == "encdec":
            return (batch["frames"],)
        return ()

    def forward(self, params: Params, batch: Batch,
                impl: str = "reference") -> Tuple[jax.Array, Dict]:
        return self.m.forward(params, batch["tokens"], *self._extra(batch),
                              impl=impl)

    def loss(self, params: Params, batch: Batch, impl: str = "reference"
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Token cross-entropy (+ z-loss, + MoE aux) in fp32.

        The gold-logit gather is a one-hot *contraction* (not
        take_along_axis): under GSPMD with vocab-sharded logits the
        contraction stays sharded and only [B,S] partials are all-reduced
        — take_along_axis would all-gather the full fp32 logits
        (≈400 GB/device for qwen2 train_4k; see EXPERIMENTS §Perf).
        """
        cfg = self.cfg
        logits, aux = self.forward(params, batch, impl=impl)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
        mask = (labels >= 0).astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        xent = jnp.sum((logz - gold) * mask) / denom
        zloss = 1e-4 * jnp.sum(jnp.square(logz) * mask) / denom
        total = xent + zloss
        metrics = {"xent": xent, "zloss": zloss}
        if cfg.moe is not None:
            lb = aux.get("load_balance_loss", 0.0)
            rz = aux.get("router_z_loss", 0.0)
            total = total + cfg.moe.aux_loss_weight * lb \
                + cfg.moe.router_z_weight * rz
            metrics["moe_lb"] = lb
            metrics["moe_rz"] = rz
            metrics["moe_dropped"] = aux.get("dropped_fraction", 0.0)
        metrics["loss"] = total
        return total, metrics

    def prefill(self, params: Params, batch: Batch, max_len: int,
                impl: str = "reference"):
        return self.m.prefill(params, batch["tokens"], *self._extra(batch),
                              max_len, impl=impl)

    def decode_step(self, params: Params, tokens: jax.Array, cache,
                    impl: str = "reference"):
        return self.m.decode_step(params, tokens, cache, impl=impl)

    def init_cache(self, batch: int, max_len: int):
        return self.m.init_cache(batch, max_len)


def build_model(cfg: ModelConfig) -> ArchModel:
    return ArchModel(cfg)


# ===================================================== input constructors

def make_batch(cfg: ModelConfig, batch: int, seq: int, key: jax.Array
               ) -> Batch:
    """Concrete random batch (smoke tests / CPU examples)."""
    ks = jax.random.split(key, 3)
    out: Batch = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size,
                                     jnp.int32),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size,
                                     jnp.int32),
    }
    if cfg.is_vlm:
        out["vision_embeds"] = jax.random.normal(
            ks[2], (batch, cfg.num_vision_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        out["frames"] = jax.random.normal(
            ks[2], (batch, cfg.num_audio_frames, cfg.d_model), jnp.float32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Batch:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation).

    For train/prefill kinds these are the model inputs at (global_batch,
    seq_len); decode kinds instead describe the one-new-token step and are
    paired with a cache spec built by the dry-run itself.
    """
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    sds = jax.ShapeDtypeStruct
    out: Batch = {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
    }
    if cfg.is_vlm:
        out["vision_embeds"] = sds(
            (B, cfg.num_vision_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        out["frames"] = sds(
            (B, cfg.num_audio_frames, cfg.d_model), jnp.float32)
    return out
