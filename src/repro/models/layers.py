"""Functional layer library (pure JAX, no flax).

Conventions
-----------
* every module has ``init_<name>(key, ...) -> params`` (nested dict of
  fp32 arrays) and ``<name>(params, x, ...) -> y`` applies;
* compute runs in ``cfg.dtype`` (bf16 by default) with fp32 accumulation
  where it matters (norms, softmax, router);
* parameter dict keys are stable and meaningful — the sharding policy
  (``repro.parallel.policy``) dispatches PartitionSpecs on them;
* attention takes ``impl`` ∈ {"reference", "pallas"}: the reference path is
  pure jnp (used by CPU smoke tests and the compiled dry-run), the pallas
  path calls the TPU kernels in ``repro.kernels``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel import act_sharding as act

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _remat_policy(cfg: ModelConfig):
    """jax.checkpoint policy from cfg.remat_policy."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_saveable
    return None  # full recompute


def scan_or_unroll(body, carry, xs, use_scan: bool):
    """``lax.scan`` or an equivalent unrolled python loop.

    The unrolled form exists for the dry-run's FLOP calibration: XLA's
    HLO cost analysis visits a while-loop body ONCE, so scanned stacks
    under-report flops/bytes by ~L×.  The dry-run lowers small *unrolled*
    depths and extrapolates (see repro.launch.dryrun).  Production paths
    keep ``use_scan=True`` (O(1) HLO size).
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


# =============================================================== norms

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(dt)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(dt)


def init_norm(cfg: ModelConfig) -> Params:
    return init_layernorm(cfg.d_model) if cfg.norm == "layernorm" \
        else init_rmsnorm(cfg.d_model)


def norm(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    return layernorm(params, x) if cfg.norm == "layernorm" else rmsnorm(params, x)


# ================================================================ rope

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    freqs = rope_frequencies(x.shape[-1], theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Transformer sinusoidal embeddings; positions [...,S] -> [...,S,D]."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(10000.0) / max(1, half - 1)))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# =========================================================== projections

def _dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def init_attention(key, cfg: ModelConfig) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], d, h * hd),
        "wk": _dense_init(ks[1], d, kv * hd),
        "wv": _dense_init(ks[2], d, kv * hd),
        "wo": _dense_init(ks[3], h * hd, d, scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    return p


def _project_qkv(params: Params, cfg: ModelConfig, x: jax.Array,
                 kv_input: Optional[jax.Array] = None):
    """Project to q [B,S,H,Dh] and k,v [B,T,KV,Dh] (cross attn: kv_input)."""
    dt = x.dtype
    src = x if kv_input is None else kv_input
    q = x @ params["wq"].astype(dt)
    k = src @ params["wk"].astype(dt)
    v = src @ params["wv"].astype(dt)
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    B, S = x.shape[:2]
    T = src.shape[1]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    return act.constrain_qkv(q, k, v)


def sdpa_reference(
    q: jax.Array,  # [B, S, H, Dh]
    k: jax.Array,  # [B, T, KV, Dh]
    v: jax.Array,  # [B, T, KV, Dh]
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset: Optional[jax.Array] = None,  # absolute position of q[0]
    kv_positions: Optional[jax.Array] = None,  # [B, T] absolute pos (ring)
    kv_valid: Optional[jax.Array] = None,  # [B, T] bool
) -> jax.Array:
    """Pure-jnp grouped-query attention with causal / sliding-window masks.

    This is the oracle the Pallas kernels are tested against, and the path
    the compiled dry-run lowers (kernels do not lower on host CPU).
    """
    B, S, H, Dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    # keep q/k in their storage dtype and accumulate the dot in fp32:
    # forward values are identical to an explicit fp32 upcast, but the
    # backward cotangents stay bf16 — the fp32-upcast form produced fp32
    # [B,S,D] all-reduces at every TP boundary (EXPERIMENTS §Perf iter 7).
    qf = q.reshape(B, S, KV, G, Dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k,
                        preferred_element_type=jnp.float32) / math.sqrt(Dh)

    if q_offset is None:
        q_off = jnp.zeros((B,), jnp.int32)
    else:
        q_off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    qp = q_off[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # [B,S]
    if kv_positions is None:
        k_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None, :], (B, T))
    else:
        k_pos = kv_positions.astype(jnp.int32)

    mask = jnp.ones((B, S, T), bool)
    if causal:
        mask &= k_pos[:, None, :] <= qp[:, :, None]
        if window is not None:
            mask &= k_pos[:, None, :] > qp[:, :, None] - window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, Dh).astype(q.dtype)


def attention(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: Optional[jax.Array] = None,
    kv_input: Optional[jax.Array] = None,  # cross attention source
    causal: bool = True,
    impl: str = "reference",
) -> jax.Array:
    """Full attention sub-layer (projections + SDPA + output)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, kv_input)
    if kv_input is None and cfg.use_rope:  # self attention: rotate q and k
        pos = positions if positions is not None else jnp.arange(S)[None, :]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    window = cfg.sliding_window if cfg.attention == "swa" else None
    if kv_input is not None:
        causal, window = False, None
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = sdpa_reference(q, k, v, causal=causal, window=window)
    out = act.constrain_attn_out(out).reshape(B, S, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"].astype(x.dtype)


# ================================================================= mlp

def init_mlp(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense_init(ks[0], d_model, d_ff),
        "wu": _dense_init(ks[1], d_model, d_ff),
        "wd": _dense_init(ks[2], d_ff, d_model, scale=1.0 / math.sqrt(d_ff)),
    }


def mlp(params: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jax.nn.silu(act.constrain_ff(x @ params["wg"].astype(dt)))
    u = act.constrain_ff(x @ params["wu"].astype(dt))
    return act.constrain_tokens((g * u) @ params["wd"].astype(dt))


# ================================================================= moe

class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    dropped_fraction: jax.Array


def _positions_by_sort(expert_idx: jax.Array, num_experts: int) -> jax.Array:
    """Rank of each (token, slot) routing pair within its expert queue.

    Equivalent to the exclusive cumsum of the flattened one-hot matrix
    (token-major priority) but via a stable argsort — O(P log P)
    comparisons instead of an O(P·E) reduce-window.
    expert_idx: [T, k] -> positions [T, k] int32.
    """
    T, k = expert_idx.shape
    P = T * k
    e_flat = expert_idx.reshape(P)
    order = jnp.argsort(e_flat, stable=True)
    counts = jnp.zeros((num_experts,), jnp.int32).at[e_flat].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    ranks_sorted = jnp.arange(P, dtype=jnp.int32) - starts[e_flat[order]]
    pos = jnp.zeros((P,), jnp.int32).at[order].set(ranks_sorted)
    return pos.reshape(T, k)


def init_moe(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts
    p = {
        "router": _dense_init(ks[0], d, e, scale=0.02),
        # stacked expert weights: [E, D, F] / [E, F, D]
        "experts_wg": jax.random.normal(ks[1], (e, d, f), jnp.float32) / math.sqrt(d),
        "experts_wu": jax.random.normal(ks[2], (e, d, f), jnp.float32) / math.sqrt(d),
        "experts_wd": jax.random.normal(ks[3], (e, f, d), jnp.float32) / math.sqrt(f),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * m.num_shared_experts)
    return p


def moe(params: Params, cfg: ModelConfig, x: jax.Array,
        dropless: bool = False) -> Tuple[jax.Array, MoEAux]:
    """Token-choice top-k MoE with capacity-bounded dispatch/combine einsums.

    The [T,E,C] dispatch one-hots become all-to-alls under GSPMD when
    tokens are data-sharded and experts model-sharded (EP).

    ``dropless=True`` sets capacity = T (worst case) so no token is ever
    dropped — used by the decode paths, where T is small and exact parity
    with the training-time forward matters (see tests).  Decode-side
    efficient dropless (sorted grouped GEMM) is a §Perf item.
    """
    m = cfg.moe
    dt = x.dtype
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)  # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)  # [T,k]
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    if m.dispatch_mode == "scatter":
        # --------- grouped scatter dispatch (GShard-style local capacity).
        # Tokens are split into `groups` = |dp| slices (one per data
        # shard); each group routes into per-group expert-capacity slots,
        # so the expert GEMM batch dims (group, expert) shard over
        # (data, model) — no replicated expert compute, and the
        # scatter/gather stays shard-local.  Positions come from a
        # stable sort (O(P log P) comparisons) instead of the [T·k, E]
        # cumsum, whose reduce-window lowering cost-counts ~quadratically
        # (see EXPERIMENTS §Perf, iteration 2).
        ctx = act.current()
        groups = 1
        if ctx is not None and not ctx.serve:
            # serve mode keeps tokens replicated (see act_sharding):
            # grouping would scatter them across dp and gather back per
            # layer — decode keeps groups=1 (experts stay model-sharded).
            gsz = ctx.policy._axis_size(ctx.policy.dp)
            if T % gsz == 0:
                groups = gsz
        Tg = T // groups
        capacity = Tg if dropless else max(
            1, int(m.capacity_factor * Tg * m.top_k / m.num_experts))
        E = m.num_experts
        eg = expert_idx.reshape(groups, Tg, m.top_k)
        gateg = gate_vals.reshape(groups, Tg, m.top_k)
        xg = act.constrain(xt.reshape(groups, Tg, D), "dp", None, None,
                           what="moe.xg")

        pos = jax.vmap(lambda e: _positions_by_sort(e, E))(eg)
        kept = pos < capacity  # [g, Tg, k]
        dest = jnp.where(kept, eg * capacity + pos,
                         E * capacity).astype(jnp.int32)

        def disp(x1, d1):  # per group: scatter tokens into expert slots
            buf = jnp.zeros((E * capacity + 1, D), dt)
            for kk in range(m.top_k):
                buf = buf.at[d1[:, kk]].add(x1)
            return buf[:-1].reshape(E, capacity, D)

        xe = jax.vmap(disp)(xg, dest)  # [g, E, C, D]
        xe = act.constrain(xe, "dp", "tp", None, None, what="moe.xe")
        g_ = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                                    params["experts_wg"].astype(dt)))
        u_ = jnp.einsum("gecd,edf->gecf", xe,
                        params["experts_wu"].astype(dt))
        ye = jnp.einsum("gecf,efd->gecd", g_ * u_,
                        params["experts_wd"].astype(dt))
        ye = act.constrain(ye, "dp", "tp", None, None, what="moe.ye")

        def comb(y1, d1, g1):  # per group: gather slots back to tokens
            flat = jnp.concatenate(
                [y1.reshape(E * capacity, D), jnp.zeros((1, D), dt)])
            out = jnp.zeros((Tg, D), dt)
            for kk in range(m.top_k):
                out = out + g1[:, kk, None].astype(dt) * flat[d1[:, kk]]
            return out

        y = jax.vmap(comb)(ye, dest, gateg).reshape(T, D)
        onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
        dispatch_sum = jnp.sum(kept.astype(jnp.float32))
    else:
        capacity = T if dropless else max(
            1, int(m.capacity_factor * T * m.top_k / m.num_experts))
        onehot = jax.nn.one_hot(expert_idx, m.num_experts,
                                dtype=jnp.float32)  # [T,k,E]
        # position of each (token, slot) within its expert queue
        flat = onehot.reshape(T * m.top_k, m.num_experts)
        pos = jnp.cumsum(flat, axis=0) - flat  # exclusive cumsum
        pos = pos.reshape(T, m.top_k, m.num_experts)
        keep = (pos < capacity) * onehot  # [T,k,E]
        pos_cap = jnp.einsum("tke,tke->tk", pos, keep).astype(jnp.int32)
        # --------- one-hot einsum dispatch (naive reference; §Perf base).
        slot_oh = jax.nn.one_hot(pos_cap, capacity, dtype=jnp.float32)  # [T,k,C]
        dispatch = act.constrain_dispatch(
            jnp.einsum("tke,tkc->tec", keep, slot_oh))  # [T,E,C]
        combine = act.constrain_dispatch(
            jnp.einsum("tec,tk,tke->tec", dispatch,
                       gate_vals.astype(jnp.float32), onehot))

        xe = act.constrain_expert(
            jnp.einsum("tec,td->ecd", dispatch.astype(dt), xt))  # [E,C,D]
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["experts_wg"].astype(dt)))
        u = jnp.einsum("ecd,edf->ecf", xe, params["experts_wu"].astype(dt))
        ye = act.constrain_expert(
            jnp.einsum("ecf,efd->ecd", g * u, params["experts_wd"].astype(dt)))
        y = jnp.einsum("tec,ecd->td", combine.astype(dt), ye)  # [T,D]
        dispatch_sum = jnp.sum(dispatch)

    if "shared" in params:
        y = y + mlp(params["shared"], xt)

    # Switch-transformer load-balance + router z losses.
    frac_tokens = jnp.mean(onehot[:, 0, :], axis=0)  # top-1 routing fraction
    frac_probs = jnp.mean(probs, axis=0)
    lb = m.num_experts * jnp.sum(frac_tokens * frac_probs)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - dispatch_sum / (T * m.top_k)
    return y.reshape(B, S, D), MoEAux(lb, z, dropped)


# =============================================================== mamba

def init_mamba(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d, di = cfg.d_model, cfg.d_inner
    ks = jax.random.split(key, 6)
    # S4D-real initialization of A.
    a = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[0], (di,), jnp.float32)
        * (math.log(0.1) - math.log(0.001)) + math.log(0.001)
    )
    return {
        "w_in": _dense_init(ks[1], d, 2 * di),
        "conv_w": jax.random.normal(ks[2], (s.d_conv, di), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "w_x": _dense_init(ks[3], di, s.dt_rank + 2 * s.d_state),
        "w_dt": _dense_init(ks[4], s.dt_rank, di, scale=s.dt_rank ** -0.5),
        # softplus^-1(dt) bias so initial dt matches dt_init
        "b_dt": jnp.log(jnp.expm1(dt_init)),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": _dense_init(ks[5], di, d),
    }


def _ssm_scan_chunked(da: jax.Array, dbx: jax.Array, h0: jax.Array,
                      chunk: int = 256):
    """Chunked parallel selective scan.

    h_t = da_t * h_{t-1} + dbx_t  over time;  da/dbx: [B,S,di,n].
    Within a chunk uses an associative scan (parallel, MXU-friendly);
    chunk carries propagate via lax.scan.  This bounds live memory to
    [B,chunk,di,n] — the same blocking the Pallas kernel uses in VMEM.
    Returns (h: [B,S,di,n], h_final: [B,di,n]).
    """
    B, S, di, n = da.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dbx = jnp.pad(dbx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // chunk
    da_c = da.reshape(B, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    dbx_c = dbx.reshape(B, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, inputs):
        a, b = inputs  # [B, chunk, di, n]
        aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_all = aa * h[:, None] + bb  # [B, chunk, di, n]
        return h_all[:, -1], h_all

    h_final, h_chunks = jax.lax.scan(chunk_step, h0, (da_c, dbx_c))
    h = h_chunks.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, di, n)
    return h[:, :S], h_final


def mamba(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    conv_state: Optional[jax.Array] = None,  # [B, d_conv-1, di]
    ssm_state: Optional[jax.Array] = None,  # [B, di, n]
    return_state: bool = False,
    impl: str = "reference",
):
    """Mamba-1 block (selective state-space) — prefill/train form.

    With ``return_state`` also emits (conv_state, ssm_state) for decoding.
    """
    s = cfg.ssm
    dt_ = x.dtype
    B, S, D = x.shape
    di = cfg.d_inner

    xz = act.constrain_ff(x @ params["w_in"].astype(dt_))
    xp, z = jnp.split(xz, 2, axis=-1)  # [B,S,di] each

    # causal depthwise conv, width d_conv
    if conv_state is None:
        xp_pad = jnp.pad(xp, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    else:
        xp_pad = jnp.concatenate([conv_state.astype(dt_), xp], axis=1)
    new_conv_state = xp_pad[:, -(s.d_conv - 1):, :] if return_state else None
    conv_w = params["conv_w"].astype(dt_)
    xc = sum(
        xp_pad[:, i:i + S, :] * conv_w[i][None, None, :]
        for i in range(s.d_conv)
    ) + params["conv_b"].astype(dt_)
    xc = jax.nn.silu(xc)

    dbc = xc @ params["w_x"].astype(dt_)  # [B,S,r+2n]
    dt_raw = dbc[..., : s.dt_rank] @ params["w_dt"].astype(dt_)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["b_dt"]
    )  # [B,S,di] fp32
    Bc = dbc[..., s.dt_rank: s.dt_rank + s.d_state].astype(jnp.float32)
    Cc = dbc[..., s.dt_rank + s.d_state:].astype(jnp.float32)

    A = -jnp.exp(params["A_log"])  # [di,n]
    da = act.constrain(jnp.exp(dt[..., None] * A), "dp", None, "tp", None,
                       what="ssm.da")  # [B,S,di,n]
    dbx = act.constrain(
        (dt * xc.astype(jnp.float32))[..., None] * Bc[:, :, None, :],
        "dp", None, "tp", None, what="ssm.dbx")

    h0 = jnp.zeros((B, di, s.d_state), jnp.float32) if ssm_state is None \
        else ssm_state.astype(jnp.float32)
    if impl == "pallas":
        from repro.kernels.mamba_scan import ops as scan_ops

        h, h_final = scan_ops.chunked_scan(da, dbx, h0)
    else:
        h, h_final = _ssm_scan_chunked(da, dbx, h0)

    y = jnp.einsum("bsdn,bsn->bsd", h, Cc)  # fp32
    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(dt_)) * jax.nn.silu(z)
    out = y @ params["w_out"].astype(dt_)
    if return_state:
        return out, (new_conv_state, h_final)
    return out


def mamba_decode_step(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,  # [B, 1, D]
    conv_state: jax.Array,  # [B, d_conv-1, di]
    ssm_state: jax.Array,  # [B, di, n]
):
    """O(1) single-token state update: ``mamba`` at S=1 with carried state.

    Delegating to the block form keeps every op (tap-ordered conv sum,
    GEMM shapes, fp32 cast points) identical to prefill/forward, so
    teacher-forced decode is bit-exact against the full-sequence pass in
    bf16 — low-precision drift here used to flip near-tied MoE router
    top-k picks in the hybrid stack (see test_arch_smoke cache parity).
    At S=1 the chunked scan degenerates to the same h = da*h0 + dbx
    recurrence this function previously hand-inlined.
    """
    out, (new_conv_state, h) = mamba(
        params, cfg, x,
        conv_state=conv_state, ssm_state=ssm_state, return_state=True)
    return out, new_conv_state, h


# ======================================================== embed / logits

def init_embedding(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    p = {"emb": jax.random.normal(
        ks[0], (cfg.padded_vocab, cfg.d_model), jnp.float32) * 0.02}
    if not cfg.tie_embeddings:
        p["unemb"] = jax.random.normal(
            ks[1], (cfg.d_model, cfg.padded_vocab), jnp.float32
        ) / math.sqrt(cfg.d_model)
    return p


def embed(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return act.constrain_tokens(params["emb"].astype(_dtype(cfg))[tokens])


def unembed(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    w = params["unemb"] if "unemb" in params else params["emb"].T
    logits = act.constrain_logits((x @ w.astype(x.dtype)).astype(jnp.float32))
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
