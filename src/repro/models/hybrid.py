"""Jamba-family hybrid LM: interleaved attention/Mamba with MoE.

Within each group of ``cfg.hybrid_group`` (=8) layers, layer 0 is
attention and layers 1..7 are Mamba (1:7 ratio, arXiv:2403.19887); every
second layer's FFN is MoE (odd in-group positions), the rest dense MLP.
The stack scans over *groups* so HLO depth stays O(1).

KV cache exists only for the one attention layer per group — this is what
makes the long_500k decode shape feasible for Jamba.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import _remat_policy
from repro.parallel import act_sharding as act
from repro.models.config import ModelConfig

Params = Dict[str, Any]


class HybridCache(NamedTuple):
    k: jax.Array  # [G, B, T, KV, Dh]  (one attn layer per group)
    v: jax.Array
    conv: jax.Array  # [G, M, B, d_conv-1, d_inner]  (M mamba layers/group)
    ssm: jax.Array  # [G, M, B, d_inner, d_state]
    pos: jax.Array  # [B]


def _ffn_init(cfg: ModelConfig, use_moe: bool, key):
    if use_moe:
        return {"moe": L.init_moe(key, cfg)}
    return {"mlp": L.init_mlp(key, cfg.d_model, cfg.d_ff)}


def _ffn_apply(cfg: ModelConfig, p: Params, x: jax.Array,
               dropless: bool = False):
    if "moe" in p:
        y, aux = L.moe(p["moe"], cfg, x, dropless=dropless)
        return y, jnp.stack([aux.load_balance_loss, aux.router_z_loss,
                             aux.dropped_fraction])
    return L.mlp(p["mlp"], x), jnp.zeros((3,), jnp.float32)


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.is_hybrid and cfg.ssm is not None and cfg.moe is not None
        self.cfg = cfg
        g = cfg.hybrid_group
        if cfg.num_layers % g:
            raise ValueError("num_layers must be a multiple of hybrid_group")
        self.num_groups = cfg.num_layers // g
        self.mamba_per_group = g - 1
        # in-group FFN kinds: MoE on odd positions (every_k_layers == 2)
        self.use_moe = [
            (j % cfg.moe.every_k_layers) == (cfg.moe.every_k_layers - 1)
            for j in range(g)
        ]

    # ------------------------------------------------------------- init
    def _group_init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2 * cfg.hybrid_group + 2)
        group: Params = {
            "attn": {
                "ln1": L.init_norm(cfg),
                "attn": L.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg),
                "ffn": _ffn_init(cfg, self.use_moe[0], ks[1]),
            }
        }
        mamba_layers = []
        for j in range(1, cfg.hybrid_group):
            mamba_layers.append({
                "ln1": L.init_norm(cfg),
                "mamba": L.init_mamba(ks[2 * j], cfg),
                "ln2": L.init_norm(cfg),
                "ffn": _ffn_init(cfg, self.use_moe[j], ks[2 * j + 1]),
            })
        # stack the MoE-ffn and MLP-ffn mamba layers separately (structures
        # differ) preserving order metadata in self.use_moe.
        moe_stack = [m for j, m in enumerate(mamba_layers, 1) if self.use_moe[j]]
        mlp_stack = [m for j, m in enumerate(mamba_layers, 1) if not self.use_moe[j]]
        group["mamba_moe"] = jax.tree.map(lambda *a: jnp.stack(a), *moe_stack)
        if mlp_stack:
            group["mamba_mlp"] = jax.tree.map(lambda *a: jnp.stack(a), *mlp_stack)
        return group

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_emb, k_groups = jax.random.split(key)
        return {
            "embedding": L.init_embedding(k_emb, cfg),
            "groups": jax.vmap(self._group_init)(
                jax.random.split(k_groups, self.num_groups)),
            "final_norm": L.init_norm(cfg),
        }

    # ------------------------------------------------------- group apply
    def _mamba_sublayers(self, gp: Params):
        """Yield (params, in-group position) in execution order 1..g-1."""
        moe_i = mlp_i = 0
        out = []
        for j in range(1, self.cfg.hybrid_group):
            if self.use_moe[j]:
                p = jax.tree.map(lambda a: a[moe_i], gp["mamba_moe"])
                moe_i += 1
            else:
                p = jax.tree.map(lambda a: a[mlp_i], gp["mamba_mlp"])
                mlp_i += 1
            out.append(p)
        return out

    def _group_apply(self, gp: Params, x: jax.Array, positions, impl: str):
        cfg = self.cfg
        aux = jnp.zeros((3,), jnp.float32)
        p = gp["attn"]
        x = x + L.attention(p["attn"], cfg, L.norm(cfg, p["ln1"], x),
                            positions=positions, impl=impl)
        y, a = _ffn_apply(cfg, p["ffn"], L.norm(cfg, p["ln2"], x))
        x, aux = x + y, aux + a
        for p in self._mamba_sublayers(gp):
            x = x + L.mamba(p["mamba"], cfg, L.norm(cfg, p["ln1"], x),
                            impl=impl)
            y, a = _ffn_apply(cfg, p["ffn"], L.norm(cfg, p["ln2"], x))
            x, aux = x + y, aux + a
        return x, aux

    # ---------------------------------------------------------- forward
    def forward(self, params: Params, tokens: jax.Array,
                impl: str = "reference") -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = L.embed(params["embedding"], cfg, tokens)

        def body(x, gp):
            return self._group_apply(gp, x, positions, impl)

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, aux_all = L.scan_or_unroll(body, x, params["groups"],
                                      cfg.scan_layers)
        aux_sum = jnp.sum(aux_all, axis=0)
        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x)
        return logits, {"load_balance_loss": aux_sum[0],
                        "router_z_loss": aux_sum[1],
                        "dropped_fraction": aux_sum[2] / cfg.num_layers}

    # ------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int) -> HybridCache:
        cfg = self.cfg
        s = cfg.ssm
        dt = jnp.dtype(cfg.dtype)
        G, M = self.num_groups, self.mamba_per_group
        return HybridCache(
            k=jnp.zeros((G, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
            v=jnp.zeros((G, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
            conv=jnp.zeros((G, M, batch, s.d_conv - 1, cfg.d_inner), dt),
            ssm=jnp.zeros((G, M, batch, cfg.d_inner, s.d_state), jnp.float32),
            pos=jnp.zeros((batch,), jnp.int32),
        )

    def prefill(self, params: Params, tokens: jax.Array, max_len: int,
                impl: str = "reference") -> Tuple[jax.Array, HybridCache]:
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = L.embed(params["embedding"], cfg, tokens)
        pad = max_len - S
        if pad < 0:
            raise ValueError("prefill longer than cache")

        def body(x, gp):
            p = gp["attn"]
            hn = L.norm(cfg, p["ln1"], x)
            q, k, v = L._project_qkv(p["attn"], cfg, hn)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            out = L.sdpa_reference(q, k, v, causal=True)
            out = act.constrain_attn_out(out).reshape(B, S, cfg.num_heads * cfg.head_dim)
            x = x + out @ p["attn"]["wo"].astype(x.dtype)
            y, _ = _ffn_apply(cfg, p["ffn"], L.norm(cfg, p["ln2"], x))
            x = x + y
            convs, ssms = [], []
            for mp in self._mamba_sublayers(gp):
                ym, (conv, ssm) = L.mamba(
                    mp["mamba"], cfg, L.norm(cfg, mp["ln1"], x),
                    return_state=True, impl=impl)
                x = x + ym
                y, _ = _ffn_apply(cfg, mp["ffn"], L.norm(cfg, mp["ln2"], x))
                x = x + y
                convs.append(conv)
                ssms.append(ssm)
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return x, (kp, vp, jnp.stack(convs), jnp.stack(ssms))

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, (k, v, conv, ssm) = L.scan_or_unroll(body, x, params["groups"],
                                                cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x[:, -1:])
        dt = jnp.dtype(cfg.dtype)
        cache = HybridCache(k=k.astype(dt), v=v.astype(dt),
                            conv=conv.astype(dt), ssm=ssm,
                            pos=jnp.full((B,), S, jnp.int32))
        return logits, cache

    def decode_step(self, params: Params, tokens: jax.Array,
                    cache: HybridCache, impl: str = "reference"
                    ) -> Tuple[jax.Array, HybridCache]:
        cfg = self.cfg
        B = tokens.shape[0]
        T = cache.k.shape[2]
        pos = cache.pos
        x = L.embed(params["embedding"], cfg, tokens)
        j = jnp.arange(T, dtype=jnp.int32)[None, :]
        kv_valid = j < (pos + 1)[:, None]

        def body(x, scanned):
            gp, gk, gv, gconv, gssm = scanned
            p = gp["attn"]
            hn = L.norm(cfg, p["ln1"], x)
            q, k, v = L._project_qkv(p["attn"], cfg, hn)
            q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
            k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
            write = lambda buf, val: jax.vmap(
                lambda b, s, w: jax.lax.dynamic_update_slice(b, w, (s, 0, 0))
            )(buf, pos, val)
            gk, gv = write(gk, k), write(gv, v)
            out = L.sdpa_reference(q, gk, gv, causal=True, q_offset=pos,
                                   kv_valid=kv_valid)
            out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
            x = x + out @ p["attn"]["wo"].astype(x.dtype)
            y, _ = _ffn_apply(cfg, p["ffn"], L.norm(cfg, p["ln2"], x),
                              dropless=True)
            x = x + y
            new_convs, new_ssms = [], []
            for m, mp in enumerate(self._mamba_sublayers(gp)):
                ym, nc, ns = L.mamba_decode_step(
                    mp["mamba"], cfg, L.norm(cfg, mp["ln1"], x),
                    gconv[m], gssm[m])
                x = x + ym
                y, _ = _ffn_apply(cfg, mp["ffn"], L.norm(cfg, mp["ln2"], x),
                                  dropless=True)
                x = x + y
                new_convs.append(nc)
                new_ssms.append(ns)
            return x, (gk, gv, jnp.stack(new_convs), jnp.stack(new_ssms))

        x, (k, v, conv, ssm) = L.scan_or_unroll(
            body, x,
            (params["groups"], cache.k, cache.v, cache.conv, cache.ssm),
            cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x)
        return logits, HybridCache(k=k, v=v, conv=conv, ssm=ssm, pos=pos + 1)
