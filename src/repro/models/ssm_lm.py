"""Mamba-1 LM (falcon-mamba family): attention-free selective-SSM stack.

Decode keeps O(1) state per layer — (conv window, SSM state) — so the
long_500k shape needs no KV cache at all (DESIGN §Arch-applicability).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import _remat_policy
from repro.models.config import ModelConfig

Params = Dict[str, Any]


class MambaCache(NamedTuple):
    conv: jax.Array  # [L, B, d_conv-1, d_inner]
    ssm: jax.Array  # [L, B, d_inner, d_state]
    pos: jax.Array  # [B]


class MambaLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.ssm is not None
        self.cfg = cfg

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg

        def layer_init(k):
            return {"ln": L.init_norm(cfg), "mamba": L.init_mamba(k, cfg)}

        k_emb, k_layers = jax.random.split(key)
        return {
            "embedding": L.init_embedding(k_emb, cfg),
            "layers": jax.vmap(layer_init)(
                jax.random.split(k_layers, cfg.num_layers)),
            "final_norm": L.init_norm(cfg),
        }

    def forward(self, params: Params, tokens: jax.Array,
                impl: str = "reference") -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        x = L.embed(params["embedding"], cfg, tokens)

        def body(x, p):
            y = L.mamba(p["mamba"], cfg, L.norm(cfg, p["ln"], x), impl=impl)
            return x + y, None

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, _ = L.scan_or_unroll(body, x, params["layers"], cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        return L.unembed(params["embedding"], cfg, x), {}

    # ------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_len: int) -> MambaCache:
        cfg = self.cfg
        s = cfg.ssm
        dt = jnp.dtype(cfg.dtype)
        return MambaCache(
            conv=jnp.zeros(
                (cfg.num_layers, batch, s.d_conv - 1, cfg.d_inner), dt),
            ssm=jnp.zeros(
                (cfg.num_layers, batch, cfg.d_inner, s.d_state), jnp.float32),
            pos=jnp.zeros((batch,), jnp.int32),
        )

    def prefill(self, params: Params, tokens: jax.Array, max_len: int,
                impl: str = "reference") -> Tuple[jax.Array, MambaCache]:
        cfg = self.cfg
        B, S = tokens.shape
        x = L.embed(params["embedding"], cfg, tokens)

        def body(x, p):
            y, (conv, ssm) = L.mamba(
                p["mamba"], cfg, L.norm(cfg, p["ln"], x),
                return_state=True, impl=impl)
            return x + y, (conv, ssm)

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, (conv, ssm) = L.scan_or_unroll(body, x, params["layers"],
                                          cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x[:, -1:])
        cache = MambaCache(conv=conv.astype(jnp.dtype(cfg.dtype)), ssm=ssm,
                           pos=jnp.full((B,), S, jnp.int32))
        return logits, cache

    def decode_step(self, params: Params, tokens: jax.Array,
                    cache: MambaCache, impl: str = "reference"
                    ) -> Tuple[jax.Array, MambaCache]:
        cfg = self.cfg
        x = L.embed(params["embedding"], cfg, tokens)

        def body(x, scanned):
            p, conv, ssm = scanned
            y, new_conv, new_ssm = L.mamba_decode_step(
                p["mamba"], cfg, L.norm(cfg, p["ln"], x), conv, ssm)
            return x + y, (new_conv, new_ssm)

        x, (conv, ssm) = L.scan_or_unroll(
            body, x, (params["layers"], cache.conv, cache.ssm),
            cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x)
        return logits, MambaCache(conv=conv, ssm=ssm, pos=cache.pos + 1)
