"""Llama-3.2-Vision-style VLM backbone: gated cross-attention image layers.

Every ``cfg.cross_attn_every``-th layer is a cross-attention layer reading
stubbed vision-patch embeddings (the modality frontend is a stub per the
assignment: ``input_specs()`` supplies precomputed patch embeddings).
Gates (tanh, init 0) make the cross layers identity at init, as in the
reference architecture.  The stack scans over groups of
(every-1 self layers + 1 cross layer).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import _remat_policy
from repro.parallel import act_sharding as act
from repro.models.config import ModelConfig

Params = Dict[str, Any]


class VLMCache(NamedTuple):
    k: jax.Array  # [G, Ls, B, T, KV, Dh]   self-attn layers per group
    v: jax.Array
    xk: jax.Array  # [G, B, Nv, KV, Dh]     static cross-attn kv
    xv: jax.Array
    pos: jax.Array  # [B]


class VisionLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.is_vlm
        self.cfg = cfg
        e = cfg.cross_attn_every
        if cfg.num_layers % e:
            raise ValueError("num_layers must be a multiple of cross_attn_every")
        self.num_groups = cfg.num_layers // e
        self.self_per_group = e - 1

    # ------------------------------------------------------------- init
    def _group_init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 2 * self.self_per_group + 3)

        def self_layer(i):
            return {
                "ln1": L.init_norm(cfg),
                "attn": L.init_attention(ks[2 * i], cfg),
                "ln2": L.init_norm(cfg),
                "mlp": L.init_mlp(ks[2 * i + 1], cfg.d_model, cfg.d_ff),
            }

        layers = [self_layer(i) for i in range(self.self_per_group)]
        return {
            "self": jax.tree.map(lambda *a: jnp.stack(a), *layers),
            "cross": {
                "ln1": L.init_norm(cfg),
                "attn": L.init_attention(ks[-2], cfg),
                "gate_attn": jnp.zeros((), jnp.float32),
                "ln2": L.init_norm(cfg),
                "mlp": L.init_mlp(ks[-1], cfg.d_model, cfg.d_ff),
                "gate_mlp": jnp.zeros((), jnp.float32),
            },
        }

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_emb, k_groups = jax.random.split(key)
        return {
            "embedding": L.init_embedding(k_emb, cfg),
            "groups": jax.vmap(self._group_init)(
                jax.random.split(k_groups, self.num_groups)),
            "final_norm": L.init_norm(cfg),
        }

    # ------------------------------------------------------------- apply
    def _cross_apply(self, p: Params, x: jax.Array, vision: jax.Array,
                     impl: str):
        cfg = self.cfg
        attn_out = L.attention(p["attn"], cfg, L.norm(cfg, p["ln1"], x),
                               kv_input=vision, impl=impl)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * attn_out
        y = L.mlp(p["mlp"], L.norm(cfg, p["ln2"], x))
        return x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * y

    def forward(self, params: Params, tokens: jax.Array,
                vision_embeds: jax.Array, impl: str = "reference"
                ) -> Tuple[jax.Array, Dict]:
        """tokens [B,S]; vision_embeds [B,Nv,D] (stub frontend output)."""
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = L.embed(params["embedding"], cfg, tokens)
        vis = vision_embeds.astype(x.dtype)

        def body(x, gp):
            for i in range(self.self_per_group):
                p = jax.tree.map(lambda a: a[i], gp["self"])
                x = x + L.attention(p["attn"], cfg, L.norm(cfg, p["ln1"], x),
                                    positions=positions, impl=impl)
                x = x + L.mlp(p["mlp"], L.norm(cfg, p["ln2"], x))
            x = self._cross_apply(gp["cross"], x, vis, impl)
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, _ = L.scan_or_unroll(body, x, params["groups"], cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        return L.unembed(params["embedding"], cfg, x), {}

    # ------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int) -> VLMCache:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        G, Ls = self.num_groups, self.self_per_group
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        return VLMCache(
            k=jnp.zeros((G, Ls, batch, max_len, kv, hd), dt),
            v=jnp.zeros((G, Ls, batch, max_len, kv, hd), dt),
            xk=jnp.zeros((G, batch, cfg.num_vision_tokens, kv, hd), dt),
            xv=jnp.zeros((G, batch, cfg.num_vision_tokens, kv, hd), dt),
            pos=jnp.zeros((batch,), jnp.int32),
        )

    def prefill(self, params: Params, tokens: jax.Array,
                vision_embeds: jax.Array, max_len: int,
                impl: str = "reference") -> Tuple[jax.Array, VLMCache]:
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = L.embed(params["embedding"], cfg, tokens)
        vis = vision_embeds.astype(x.dtype)
        pad = max_len - S

        def body(x, gp):
            ks, vs = [], []
            for i in range(self.self_per_group):
                p = jax.tree.map(lambda a: a[i], gp["self"])
                hn = L.norm(cfg, p["ln1"], x)
                q, k, v = L._project_qkv(p["attn"], cfg, hn)
                q = L.apply_rope(q, positions, cfg.rope_theta)
                k = L.apply_rope(k, positions, cfg.rope_theta)
                out = L.sdpa_reference(q, k, v, causal=True)
                out = act.constrain_attn_out(out).reshape(B, S, cfg.num_heads * cfg.head_dim)
                x = x + out @ p["attn"]["wo"].astype(x.dtype)
                x = x + L.mlp(p["mlp"], L.norm(cfg, p["ln2"], x))
                ks.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))))
                vs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))
            cp = gp["cross"]
            _, xk, xv = L._project_qkv(cp["attn"], cfg,
                                       L.norm(cfg, cp["ln1"], x), kv_input=vis)
            x = self._cross_apply(cp, x, vis, impl)
            return x, (jnp.stack(ks), jnp.stack(vs), xk, xv)

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, (k, v, xk, xv) = L.scan_or_unroll(body, x, params["groups"],
                                             cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x[:, -1:])
        dt = jnp.dtype(cfg.dtype)
        cache = VLMCache(k=k.astype(dt), v=v.astype(dt), xk=xk.astype(dt),
                         xv=xv.astype(dt), pos=jnp.full((B,), S, jnp.int32))
        return logits, cache

    def decode_step(self, params: Params, tokens: jax.Array,
                    cache: VLMCache, impl: str = "reference"
                    ) -> Tuple[jax.Array, VLMCache]:
        cfg = self.cfg
        B = tokens.shape[0]
        T = cache.k.shape[3]
        pos = cache.pos
        x = L.embed(params["embedding"], cfg, tokens)
        j = jnp.arange(T, dtype=jnp.int32)[None, :]
        kv_valid = j < (pos + 1)[:, None]

        def body(x, scanned):
            gp, gk, gv, gxk, gxv = scanned
            new_k, new_v = [], []
            for i in range(self.self_per_group):
                p = jax.tree.map(lambda a: a[i], gp["self"])
                hn = L.norm(cfg, p["ln1"], x)
                q, k, v = L._project_qkv(p["attn"], cfg, hn)
                q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
                k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
                write = lambda buf, val: jax.vmap(
                    lambda b, s, w: jax.lax.dynamic_update_slice(b, w, (s, 0, 0))
                )(buf, pos, val)
                lk, lv = write(gk[i], k), write(gv[i], v)
                out = L.sdpa_reference(q, lk, lv, causal=True, q_offset=pos,
                                       kv_valid=kv_valid)
                out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
                x = x + out @ p["attn"]["wo"].astype(x.dtype)
                x = x + L.mlp(p["mlp"], L.norm(cfg, p["ln2"], x))
                new_k.append(lk)
                new_v.append(lv)
            cp = gp["cross"]
            hn = L.norm(cfg, cp["ln1"], x)
            q = (hn @ cp["attn"]["wq"].astype(x.dtype)).reshape(
                B, 1, cfg.num_heads, cfg.head_dim)
            out = L.sdpa_reference(q, gxk, gxv, causal=False)
            out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
            out = out @ cp["attn"]["wo"].astype(x.dtype)
            x = x + jnp.tanh(cp["gate_attn"]).astype(x.dtype) * out
            y = L.mlp(cp["mlp"], L.norm(cfg, cp["ln2"], x))
            x = x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * y
            return x, (jnp.stack(new_k), jnp.stack(new_v))

        x, (k, v) = L.scan_or_unroll(
            body, x,
            (params["groups"], cache.k, cache.v, cache.xk, cache.xv),
            cfg.scan_layers)
        x = L.norm(cfg, params["final_norm"], x)
        logits = L.unembed(params["embedding"], cfg, x)
        return logits, VLMCache(k=k, v=v, xk=cache.xk, xv=cache.xv,
                                pos=pos + 1)
