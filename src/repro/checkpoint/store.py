"""Distributed checkpoint store (npz shards + JSON manifest).

Design points that matter at fleet scale, implemented here at
container scale with the same interfaces:

* **atomic commits** — writes land in ``step_<k>.tmp`` and are renamed
  only after the manifest fsyncs, so a preempted save can never be
  restored from;
* **async saves** — a background thread snapshots (device_get) then
  serializes, keeping the train loop compute-bound;
* **mesh-independent restore** — arrays are stored as *global* logical
  tensors; restore ``device_put``s them under whatever sharding the new
  mesh prescribes, which is what makes elastic resizes (256 ↔ 512 chips)
  a pure control-plane operation (tested in
  ``tests/test_checkpoint.py::test_elastic_reshard``);
* **keep-last-N** garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, List, Optional

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _wire_form(a: np.ndarray) -> np.ndarray:
    """npz-safe representation (bf16/fp8 ride as unsigned ints)."""
    if a.dtype.kind == "V" or a.dtype.name in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
    return a


def _from_wire(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if a.dtype.name != dtype_name:
        import ml_dtypes

        return a.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return a


def save_pytree(tree: PyTree, directory: str, step: int) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": _wire_form(a) for i, a in enumerate(host_leaves)})
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(a.shape) for a in host_leaves],
        "dtypes": [str(a.dtype) for a in host_leaves],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_pytree(template: PyTree, directory: str, step: int,
                   sharding_fn: Optional[Callable[[str], Any]] = None
                   ) -> PyTree:
    """Restore into the structure of ``template``.

    ``sharding_fn(path) -> Sharding`` lets the caller re-shard each leaf
    for a *different* mesh than the one that saved it (elastic scaling).
    """
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(src, "arrays.npz"))
    arrays = [_from_wire(data[f"a{i}"], dt)
              for i, dt in enumerate(manifest["dtypes"])]

    paths, leaves, treedef = _flatten_with_paths(template)
    if paths != manifest["paths"]:
        raise ValueError(
            "checkpoint tree mismatch:\n"
            f"  missing: {set(manifest['paths']) - set(paths)}\n"
            f"  extra:   {set(paths) - set(manifest['paths'])}")
    out = []
    for path, leaf, arr in zip(paths, leaves, arrays):
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{path}: shape {arr.shape} != {leaf.shape}")
        if sharding_fn is not None:
            out.append(jax.device_put(arr, sharding_fn(path)))
        else:
            out.append(jax.device_put(arr.astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


class CheckpointManager:
    """Async save + keep-last-N retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, tree: PyTree, step: int, blocking: bool = False) -> None:
        # Snapshot on the caller's thread (cheap device_get at CPU scale;
        # on TPU this is the only device-blocking part).
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_pytree(host, self.directory, step)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, template: PyTree,
                       sharding_fn=None) -> Optional[tuple]:
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None
        return step, restore_pytree(template, self.directory, step,
                                    sharding_fn)

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
