"""Seeded fault-injection schedules (chaos engineering for the engine).

The paper's robustness story (§6.2.2, Fig. 9) covers a single failure
mode — OOMKilled pods.  Real Kubernetes clusters lose whole nodes, flap,
and suffer correlated memory storms; this module makes those failure
modes *declarative and deterministic* so chaos runs are reproducible
experiments, not flaky ones.

A fault schedule is a builder registered in
:data:`repro.api.registry.FAULTS` that returns a list of
:class:`FaultEvent` — ``(t, EventKind, payload)`` triples the engine
pushes onto its event queue at construction.  Builders receive the
cluster size (``num_nodes``) and a ``seed`` from the engine (from
``FaultConfig``), so the *same* config replays the *same* faults bit for
bit — the chaos determinism suite in ``tests/test_chaos.py`` holds two
runs of a seeded schedule to identical results.

Built-in schedules:

* ``node_crash`` — permanently crash ``nodes`` distinct (seed-chosen)
  nodes at time ``at``.  Running pods on those nodes terminate
  ``FAILED`` and re-enter admission through the engine's HEAL path.
* ``node_flap`` — down/up pairs: the same seed-chosen nodes go offline
  at ``at`` (+ ``period`` per repeat) and recover ``down_for`` seconds
  later, exercising capacity loss *and* restoration through the
  dirty-tile path into the device-resident allocator state.
* ``oom_storm`` — at each firing, force-OOM the ``victims``
  longest-running pods (lowest uid — deterministic without a host
  registry scan), driving the Fig-9 self-healing path under correlated
  memory pressure instead of a single mistuned quota.
* ``none`` — the empty schedule (the ``FaultConfig`` default).

Schedules compose into scenarios via
:class:`repro.api.config.FaultConfig` (``EngineConfig.faults``), which
also carries the graceful-degradation knobs: bounded retry budgets,
exponential backoff and the per-workflow deadline.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import numpy as np

from repro.api.registry import FAULTS
from repro.engine.events import EventKind


class FaultEvent(NamedTuple):
    """One scheduled fault: pushed verbatim onto the engine's queue."""

    t: float
    kind: EventKind
    payload: Tuple = ()


def _pick_nodes(num_nodes: int, nodes: int, seed: int) -> List[int]:
    """Seed-deterministic choice of distinct victim nodes (sorted)."""
    if num_nodes < 1:
        raise ValueError(f"fault schedule needs num_nodes >= 1, "
                         f"got {num_nodes}")
    if nodes < 1:
        raise ValueError(f"fault schedule needs nodes >= 1, got {nodes}")
    rng = np.random.default_rng(seed)
    picks = rng.choice(num_nodes, size=min(nodes, num_nodes), replace=False)
    return sorted(int(n) for n in picks)


@FAULTS.register("none", doc="the empty fault schedule")
def none(num_nodes: int = 0, seed: int = 0) -> List[FaultEvent]:
    """No injected faults — the ``FaultConfig`` default."""
    return []


@FAULTS.register("node_crash", capabilities=("seeded",),
                 doc="permanently crash seed-chosen nodes at time `at`")
def node_crash(num_nodes: int, nodes: int = 1, at: float = 300.0,
               seed: int = 0) -> List[FaultEvent]:
    """Crash ``nodes`` distinct nodes at time ``at``; they never recover.

    The node choice is drawn from ``default_rng(seed)``, so a scenario's
    fault seed pins *which* nodes die, independently of the workload
    seed.
    """
    if at < 0:
        raise ValueError(f"node_crash at must be >= 0, got {at}")
    return [FaultEvent(float(at), EventKind.NODE_DOWN, (n,))
            for n in _pick_nodes(num_nodes, nodes, seed)]


@FAULTS.register("node_flap", capabilities=("seeded",),
                 doc="seed-chosen nodes go down at `at` and recover "
                     "`down_for` seconds later, `repeats` times")
def node_flap(num_nodes: int, nodes: int = 1, at: float = 300.0,
              down_for: float = 120.0, repeats: int = 1,
              period: float = 600.0, seed: int = 0,
              recovery_time: Optional[float] = None) -> List[FaultEvent]:
    """Down/up pairs for the same seed-chosen nodes.

    Repeat ``r`` takes the nodes offline at ``at + r·period`` and brings
    them back ``down_for`` seconds later — capacity leaves *and* rejoins
    the allocator's view, riding the dirty-tile path both ways.

    ``recovery_time`` is an alias for ``down_for`` under the name the
    recovery-time sweeps use (``grid(..., fault_params=...)``); when
    given it overrides ``down_for``.
    """
    if recovery_time is not None:
        down_for = float(recovery_time)
    if at < 0 or down_for <= 0 or period <= 0:
        raise ValueError(
            f"node_flap needs at >= 0, down_for > 0 and period > 0, got "
            f"at={at}, down_for={down_for}, period={period}")
    if repeats < 1:
        raise ValueError(f"node_flap repeats must be >= 1, got {repeats}")
    if down_for >= period and repeats > 1:
        raise ValueError(
            f"node_flap down_for ({down_for}) must be shorter than the "
            f"repeat period ({period}) or flaps overlap")
    picks = _pick_nodes(num_nodes, nodes, seed)
    events: List[FaultEvent] = []
    for r in range(repeats):
        t = at + r * period
        for n in picks:
            events.append(FaultEvent(t, EventKind.NODE_DOWN, (n,)))
            events.append(FaultEvent(t + down_for, EventKind.NODE_UP, (n,)))
    return sorted(events, key=lambda e: (e.t, e.kind))


@FAULTS.register("oom_storm", capabilities=("seeded",),
                 doc="force-OOM the `victims` longest-running pods at "
                     "each firing")
def oom_storm(num_nodes: int, at: float = 300.0, victims: int = 2,
              repeats: int = 1, period: float = 600.0,
              seed: int = 0) -> List[FaultEvent]:
    """Correlated memory pressure: at each firing the engine force-OOMs
    the ``victims`` longest-running pods (chosen by lowest uid at fire
    time — deterministic given the seeded simulation).  Each victim goes
    through the ordinary §6.2.2 self-healing path: OOMKilled → delete →
    re-allocate with the learned memory floor.
    """
    if at < 0 or period <= 0:
        raise ValueError(f"oom_storm needs at >= 0 and period > 0, got "
                         f"at={at}, period={period}")
    if victims < 1 or repeats < 1:
        raise ValueError(f"oom_storm needs victims >= 1 and repeats >= 1, "
                         f"got victims={victims}, repeats={repeats}")
    return [FaultEvent(at + r * period, EventKind.OOM_STORM, (victims,))
            for r in range(repeats)]
