"""Continuous serving loop over the KubeAdaptor engine.

``KubeAdaptor.run()`` is an *offline* driver: every workflow is
submitted up front, then the event loop drains to completion.  A
production docking engine (the ROADMAP's streaming north-star) never
sees the full arrival schedule — submissions keep landing while decided
bursts execute.  :class:`StreamEngine` is that serving mode, built on
the pieces this engine already has:

* **Bounded look-ahead ingestion.**  The pump submits, before each
  ``step()``, exactly the arrivals the engine is entitled to know about:
  everything due at or before the current head event's fold deadline
  (``head.t + batch_window``).  The deadline is re-anchored after every
  submission, because an arrival earlier than the current head becomes
  the head itself.  Results are therefore *identical* to submitting the
  whole schedule up front (``tests/test_incremental_state.py`` holds it
  bit-for-bit): the windowed drain already defines which arrivals a
  decision may fold, and the pump never withholds one inside the window
  nor reveals one beyond it.
* **Double-buffered ingest overlap.**  While a fused dispatch is in
  flight on device, the engine calls back into
  :meth:`StreamEngine._overlap_ingest` (the ``ingest_hook``), which
  pushes a chunk of *future* arrivals into the event queue — host work
  hidden under device compute.  Folding rules are unaffected: those
  arrivals are all beyond the current fold deadline, so they cannot
  join the in-flight decision; they are simply queued earlier.
* **Serving telemetry.**  Each step is wall-clock timed; steps that
  dispatched allocation rows contribute per-decision latency samples
  (step wall time amortized over the rows it decided).  ``serve()``
  returns :class:`StreamStats` with sustained decisions/sec and
  p50/p99 per-decision latency next to the usual engine metrics.
* **Admission control (backpressure).**  With ``max_pending`` set, the
  pump watches the engine's pending admission queue; while its backlog
  exceeds the bound, new arrivals are *shed* (dropped and counted — the
  AHPA-style graceful degradation) or *deferred* (withheld and
  submitted once the backlog drains, re-timed to the engine clock so
  time never runs backwards), per ``overload_policy``.  Overload then
  produces a measured, bounded queue instead of unbounded growth;
  ``StreamStats`` reports the shed/deferred counts.  Unset (default),
  the pump admits everything — bit-for-bit the offline run.

The stream driver works with any engine configuration; it is fastest
with the device-resident incremental state (``AllocatorConfig.
incremental_state``), where the overlap hook has a real in-flight
dispatch to hide under.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.engine.kubeadaptor import EngineMetrics, KubeAdaptor
from repro.workflows.spec import WorkflowSpec


@dataclasses.dataclass
class StreamStats:
    """Serving-loop report: throughput + tail latency + engine metrics."""

    decisions: int  # allocation rows decided (= metrics.dispatched_rows)
    dispatches: int  # fused dispatches issued
    wall_seconds: float  # total serve() wall time
    decisions_per_sec: float  # sustained throughput over the whole run
    p50_latency_s: float  # per-decision latency percentiles, wall time
    p99_latency_s: float  # of the deciding step / rows it decided
    overlapped_ingests: int  # arrivals submitted under in-flight dispatches
    shed_workflows: int  # arrivals dropped by admission control
    deferred_workflows: int  # arrivals withheld (at least once) by backlog
    metrics: EngineMetrics  # the usual offline-run metrics

    def to_dict(self) -> Dict[str, float]:
        """Schema-stable summary for benchmark JSON / CI checks."""
        return {
            "decisions": self.decisions,
            "dispatches": self.dispatches,
            "wall_seconds": self.wall_seconds,
            "decisions_per_sec": self.decisions_per_sec,
            "p50_latency_s": self.p50_latency_s,
            "p99_latency_s": self.p99_latency_s,
            "overlapped_ingests": self.overlapped_ingests,
            "shed_workflows": self.shed_workflows,
            "deferred_workflows": self.deferred_workflows,
        }


class StreamEngine:
    """Drive a :class:`KubeAdaptor` against a live arrival stream.

    ``arrivals`` is a time-sorted sequence of ``(t, WorkflowSpec)``; the
    pump feeds them to the engine just in time (see the module
    docstring), so the engine behaves exactly as if it were long-lived
    and submissions arrived from outside.
    """

    def __init__(self, engine: KubeAdaptor,
                 arrivals: Sequence[Tuple[float, WorkflowSpec]],
                 prefetch_chunk: int = 64,
                 max_pending: Optional[int] = None,
                 overload_policy: str = "shed"):
        times = [t for t, _ in arrivals]
        if any(b < a for a, b in zip(times, times[1:])):
            raise ValueError("arrivals must be sorted by time")
        if overload_policy not in ("shed", "defer"):
            raise ValueError(
                f"unknown overload_policy {overload_policy!r} "
                f"(want 'shed' or 'defer')")
        if max_pending is not None and max_pending < 0:
            raise ValueError(f"max_pending must be None (unbounded) or "
                             f">= 0, got {max_pending}")
        self.engine = engine
        self._arrivals: List[Tuple[float, WorkflowSpec]] = list(arrivals)
        self._next = 0  # first arrival not yet submitted
        self._prefetch_chunk = prefetch_chunk
        self._max_pending = max_pending
        self._overload_policy = overload_policy
        self.overlapped_ingests = 0
        self.shed_workflows = 0
        self.deferred_workflows = 0
        self._deferred_seen = 0  # arrivals counted deferred at least once
        engine.ingest_hook = self._overlap_ingest

    # ------------------------------------------------------------ ingestion
    def _backlogged(self) -> bool:
        """Admission control: is the engine's pending queue over bound?"""
        return (self._max_pending is not None
                and len(self.engine._pending) > self._max_pending)

    def _pump(self) -> None:
        """Submit every arrival the next step is entitled to see.

        The fold deadline is re-anchored after each submission: an
        arrival earlier than the current head becomes the head, and its
        own window may entitle the step to further arrivals.

        Under admission control (``max_pending``) an over-bound backlog
        sheds the arrival (dropped + counted) or defers the whole pump
        until the backlog drains — except on an empty event queue, where
        withholding would stall the loop (an empty queue also implies an
        empty pending queue: pending tasks always have a completion or
        retry scheduled, so the backlog check passes there anyway).
        Deferred arrivals whose timestamp the engine has already passed
        are submitted at the engine clock — time never runs backwards.
        """
        engine = self.engine
        while self._next < len(self._arrivals):
            head = engine.queue.peek()
            t, spec = self._arrivals[self._next]
            # The entitlement window is re-read per arrival: with
            # forecasting enabled (EngineConfig.forecast) the engine
            # sizes its fold deadline from the predicted inter-arrival
            # gap, and the pump must grant exactly that look-ahead.
            # Forecast off, this is the static batch_window as before.
            if head is not None and t > head.t + engine.fold_window():
                break
            if self._backlogged():
                if self._overload_policy == "shed":
                    self.shed_workflows += 1
                    self._next += 1
                    continue
                if self._next >= self._deferred_seen:
                    self.deferred_workflows += 1
                    self._deferred_seen = self._next + 1
                break
            # An empty queue (quiescent gap between workload phases)
            # anchors the next period on this arrival itself.
            if self._max_pending is not None:
                t = max(t, engine._now)
            engine.submit(spec, t)
            self._next += 1

    def _overlap_ingest(self) -> None:
        """Queue a chunk of future arrivals under the in-flight dispatch.

        Called by the engine between issuing a fused dispatch and
        blocking on its results.  Every remaining arrival is strictly
        beyond the current fold deadline (``_pump`` already submitted
        everything inside it), so queueing them cannot change the
        decision in flight — this is pure host-side work hidden under
        device compute.  Disabled under admission control: prefetched
        arrivals would bypass the backlog check.
        """
        if self._max_pending is not None:
            return
        end = min(self._next + self._prefetch_chunk, len(self._arrivals))
        for i in range(self._next, end):
            t, spec = self._arrivals[i]
            self.engine.submit(spec, t)
            self.overlapped_ingests += 1
        self._next = end

    # -------------------------------------------------------------- serving
    def serve(self) -> StreamStats:
        """Run the stream to completion; returns the serving report."""
        engine = self.engine
        latencies: List[float] = []
        t_serve0 = time.perf_counter()
        while True:
            self._pump()
            if not engine.queue:
                break  # arrivals exhausted and the event loop drained
            rows_before = engine.metrics.dispatched_rows
            t0 = time.perf_counter()
            engine.step()
            dt = time.perf_counter() - t0
            if engine.cfg.invariant_checks:
                engine.cluster.check_invariants()
            rows = engine.metrics.dispatched_rows - rows_before
            if rows > 0:
                latencies.extend([dt / rows] * rows)
        wall = time.perf_counter() - t_serve0
        metrics = engine.finalize()
        lat = np.asarray(latencies, np.float64)
        return StreamStats(
            decisions=metrics.dispatched_rows,
            dispatches=metrics.num_dispatches,
            wall_seconds=wall,
            decisions_per_sec=(metrics.dispatched_rows / wall
                               if wall > 0 else 0.0),
            p50_latency_s=float(np.percentile(lat, 50)) if lat.size else 0.0,
            p99_latency_s=float(np.percentile(lat, 99)) if lat.size else 0.0,
            overlapped_ingests=self.overlapped_ingests,
            shed_workflows=self.shed_workflows,
            deferred_workflows=self.deferred_workflows,
            metrics=metrics,
        )


def serve_stream(engine: KubeAdaptor,
                 arrivals: Sequence[Tuple[float, WorkflowSpec]],
                 prefetch_chunk: int = 64,
                 max_pending: Optional[int] = None,
                 overload_policy: str = "shed") -> StreamStats:
    """One-call convenience: build a :class:`StreamEngine` and serve."""
    return StreamEngine(engine, arrivals, prefetch_chunk=prefetch_chunk,
                        max_pending=max_pending,
                        overload_policy=overload_policy).serve()
