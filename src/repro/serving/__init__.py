from repro.serving.engine import ServeConfig, ServeEngine
from repro.serving.stream import StreamEngine, StreamStats, serve_stream

__all__ = ["ServeConfig", "ServeEngine", "StreamEngine", "StreamStats",
           "serve_stream"]
