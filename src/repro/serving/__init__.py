from repro.serving.engine import ServeConfig, ServeEngine

__all__ = ["ServeConfig", "ServeEngine"]
