"""Serving engine: slot-based continuous batching over the decode cache.

The engine owns ``n_slots`` cache lanes.  Each step either admits a queued
request (prefill → scatter its cache into a free slot) or advances every
active slot by one token (batched decode).  Slot admission is a resource
allocation decision — ``repro.engine.mljobs`` can drive it through ARAS,
scaling the *number of admitted lanes* exactly like the paper scales pod
quotas under contention.

Per-slot positions make the decode batch ragged-safe: finished or empty
slots are masked out, so one compiled decode_step serves any occupancy.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ArchModel, Batch


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    n_slots: int = 4
    max_len: int = 256
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


class ServeEngine:
    def __init__(self, model: ArchModel, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * cfg.n_slots
        self._req_ids = itertools.count()
        self._rng = jax.random.key(cfg.seed)
        self.cache = model.init_cache(cfg.n_slots, cfg.max_len)
        # locate each cache leaf's batch axis structurally (robust even
        # when n_slots == 1): the axis whose size tracks the batch arg.
        c2 = jax.eval_shape(lambda: model.init_cache(2, cfg.max_len))
        c3 = jax.eval_shape(lambda: model.init_cache(3, cfg.max_len))
        self._batch_axes = jax.tree.map(
            lambda a, b: int(next(
                i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                if x != y)), c2, c3)
        self._next_token = np.zeros((cfg.n_slots,), np.int32)
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c))
        self._steps = 0

    # --------------------------------------------------------------- API
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        rid = next(self._req_ids)
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return rid

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slots)

    def has_work(self) -> bool:
        return self.active > 0 or bool(self.queue)

    # ------------------------------------------------------------- steps
    def _admit(self, slot: int, req: Request) -> None:
        """Prefill the request and scatter its lane into the batch cache."""
        batch: Batch = {"tokens": jnp.asarray(req.prompt[None])}
        logits, cache1 = self.model.prefill(self.params, batch,
                                            max_len=self.cfg.max_len)

        def scatter(full, lane, axis):
            idx = [slice(None)] * full.ndim
            idx[axis] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(lane.astype(full.dtype))

        self.cache = jax.tree.map(scatter, self.cache, cache1,
                                  self._batch_axes)
        self.slots[slot] = req
        self._next_token[slot] = int(jnp.argmax(logits[0, -1]))
        req.generated.append(int(self._next_token[slot]))

    def step(self) -> Dict[int, List[int]]:
        """One engine iteration; returns newly finished request outputs."""
        self._steps += 1
        # admission: fill free slots from the queue (prefill phase)
        for slot in range(self.cfg.n_slots):
            if self.slots[slot] is None and self.queue:
                self._admit(slot, self.queue.popleft())

        finished: Dict[int, List[int]] = {}
        if self.active == 0:
            return finished

        tokens = jnp.asarray(self._next_token[:, None])
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        if self.cfg.greedy:
            nxt = jnp.argmax(logits[:, 0], axis=-1)
        else:
            self._rng, sub = jax.random.split(self._rng)
            nxt = jax.random.categorical(
                sub, logits[:, 0] / self.cfg.temperature, axis=-1)
        nxt = np.asarray(nxt, np.int32)

        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated.append(int(nxt[slot]))
            self._next_token[slot] = nxt[slot]
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                finished[req.request_id] = req.generated
                self.slots[slot] = None
        return finished

    def run_to_completion(self, max_steps: int = 10_000
                          ) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        steps = 0
        while self.has_work() and steps < max_steps:
            out.update(self.step())
            steps += 1
        return out
