"""Algorithm 2 (resource discovery) + Algorithm 1 window accumulation.

Property-based (hypothesis) companions live in
``tests/property/test_discovery_props.py`` so this module collects on a
bare jax+pytest environment.
"""
import numpy as np
import pytest

from repro.core import discovery, lifecycle
from repro.core.types import ClusterSnapshot, TaskWindow

pytestmark = pytest.mark.tier1


def make_snapshot(num_nodes, pod_node, pod_cpu, pod_mem, pod_active,
                  cap_cpu=8000.0, cap_mem=16000.0):
    return ClusterSnapshot(
        allocatable_cpu=np.full((num_nodes,), cap_cpu, np.float32),
        allocatable_mem=np.full((num_nodes,), cap_mem, np.float32),
        pod_node=np.asarray(pod_node, np.int32),
        pod_cpu=np.asarray(pod_cpu, np.float32),
        pod_mem=np.asarray(pod_mem, np.float32),
        pod_active=np.asarray(pod_active, bool),
    )


def test_residual_basic():
    snap = make_snapshot(3, [0, 0, 1, 2], [1000, 500, 2000, 100],
                         [2000, 1000, 4000, 200], [True, True, True, False])
    rc, rm = discovery.discover(snap)
    np.testing.assert_allclose(np.asarray(rc), [6500, 6000, 8000])
    np.testing.assert_allclose(np.asarray(rm), [13000, 12000, 16000])


def test_pending_counts_succeeded_does_not():
    """Alg. 2 line 8: only Running|Pending pods consume."""
    snap = make_snapshot(1, [0, 0], [1000, 1000], [1000, 1000], [True, False])
    rc, rm = discovery.discover(snap)
    assert float(rc[0]) == 7000.0


def test_empty_cluster():
    snap = ClusterSnapshot.empty(4)
    rc, rm = discovery.discover(snap)
    assert rc.shape == (4,)
    np.testing.assert_allclose(np.asarray(rc), 0.0)


def test_summary_max_node_tracks_cpu():
    """Alg. 1 lines 19-22: Re_max_mem is read from the argmax-CPU node."""
    snap = make_snapshot(2, [0], [1000], [15000], [True])
    rc, rm = discovery.discover(snap)
    s = discovery.summarize(rc, rm)
    assert int(s["max_node"]) == 1
    assert float(s["re_max_cpu"]) == 8000.0
    assert float(s["re_max_mem"]) == 16000.0  # node 1's mem, not the global max
    assert float(s["total_cpu"]) == 15000.0


# ------------------------------------------------------ lifecycle window

def test_window_demand_includes_in_window_only():
    win = TaskWindow(
        t_start=np.array([0.0, 5.0, 14.9, 15.0, 20.0], np.float32),
        cpu=np.array([100, 200, 400, 800, 1600], np.float32),
        mem=np.array([1, 2, 4, 8, 16], np.float32),
        done=np.array([False] * 5),
    )
    # window [5, 15): rows 1, 2 qualify (t=5 in, t=15 out — half-open).
    cpu, mem = lifecycle.window_demand(win, 5.0, 15.0, 1000.0, 10.0)
    assert cpu == pytest.approx(1000 + 200 + 400)
    assert mem == pytest.approx(10 + 2 + 4)


def test_window_demand_skips_done():
    win = TaskWindow(
        t_start=np.array([5.0, 6.0], np.float32),
        cpu=np.array([100, 200], np.float32),
        mem=np.array([1, 2], np.float32),
        done=np.array([True, False]),
    )
    cpu, mem = lifecycle.window_demand(win, 0.0, 10.0, 0.0, 0.0)
    assert cpu == pytest.approx(200)


def test_window_demand_empty_store():
    win = TaskWindow(*(np.zeros((0,), t) for t in (np.float32,) * 3 + (bool,)))
    cpu, mem = lifecycle.window_demand(win, 0.0, 10.0, 123.0, 456.0)
    assert (cpu, mem) == (123.0, 456.0)


def test_window_demand_batch_matches_scalar():
    """The [B,T] mask-matrix form == B scalar reductions, one dispatch."""
    win = TaskWindow(
        t_start=np.array([0.0, 5.0, 14.9, 15.0, 20.0], np.float32),
        cpu=np.array([100, 200, 400, 800, 1600], np.float32),
        mem=np.array([1, 2, 4, 8, 16], np.float32),
        done=np.array([False, False, True, False, False]),
    )
    ends = [6.0, 15.0, 25.0]
    own_cpu = [10.0, 20.0, 30.0]
    own_mem = [1.0, 2.0, 3.0]
    bc, bm = lifecycle.window_demand_batch(win, 0.0, ends, own_cpu, own_mem)
    for i in range(3):
        sc, sm = lifecycle.window_demand(win, 0.0, ends[i], own_cpu[i],
                                         own_mem[i])
        assert float(bc[i]) == pytest.approx(sc)
        assert float(bm[i]) == pytest.approx(sm)


def test_window_demand_batch_self_exclusion():
    """self_slots masks the requester's own record out of the demand."""
    win = TaskWindow(
        t_start=np.array([1.0, 2.0], np.float32),
        cpu=np.array([100.0, 200.0], np.float32),
        mem=np.array([10.0, 20.0], np.float32),
        done=np.array([False, False]),
    )
    bc, bm = lifecycle.window_demand_batch(
        win, 0.0, [10.0, 10.0], [0.0, 0.0], [0.0, 0.0], self_slots=[0, 1]
    )
    assert float(bc[0]) == pytest.approx(200.0)  # row 0 excluded itself
    assert float(bc[1]) == pytest.approx(100.0)
    assert float(bm[1]) == pytest.approx(10.0)
