"""Algorithm 2 (resource discovery) + Algorithm 1 window accumulation."""
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import discovery, lifecycle
from repro.core.types import ClusterSnapshot, TaskWindow


def make_snapshot(num_nodes, pod_node, pod_cpu, pod_mem, pod_active,
                  cap_cpu=8000.0, cap_mem=16000.0):
    return ClusterSnapshot(
        allocatable_cpu=np.full((num_nodes,), cap_cpu, np.float32),
        allocatable_mem=np.full((num_nodes,), cap_mem, np.float32),
        pod_node=np.asarray(pod_node, np.int32),
        pod_cpu=np.asarray(pod_cpu, np.float32),
        pod_mem=np.asarray(pod_mem, np.float32),
        pod_active=np.asarray(pod_active, bool),
    )


def test_residual_basic():
    snap = make_snapshot(3, [0, 0, 1, 2], [1000, 500, 2000, 100],
                         [2000, 1000, 4000, 200], [True, True, True, False])
    rc, rm = discovery.discover(snap)
    np.testing.assert_allclose(np.asarray(rc), [6500, 6000, 8000])
    np.testing.assert_allclose(np.asarray(rm), [13000, 12000, 16000])


def test_pending_counts_succeeded_does_not():
    """Alg. 2 line 8: only Running|Pending pods consume."""
    snap = make_snapshot(1, [0, 0], [1000, 1000], [1000, 1000], [True, False])
    rc, rm = discovery.discover(snap)
    assert float(rc[0]) == 7000.0


def test_empty_cluster():
    snap = ClusterSnapshot.empty(4)
    rc, rm = discovery.discover(snap)
    assert rc.shape == (4,)
    np.testing.assert_allclose(np.asarray(rc), 0.0)


def test_summary_max_node_tracks_cpu():
    """Alg. 1 lines 19-22: Re_max_mem is read from the argmax-CPU node."""
    snap = make_snapshot(2, [0], [1000], [15000], [True])
    rc, rm = discovery.discover(snap)
    s = discovery.summarize(rc, rm)
    assert int(s["max_node"]) == 1
    assert float(s["re_max_cpu"]) == 8000.0
    assert float(s["re_max_mem"]) == 16000.0  # node 1's mem, not the global max
    assert float(s["total_cpu"]) == 15000.0


@settings(max_examples=100, deadline=None)
@given(
    num_nodes=st.integers(min_value=1, max_value=16),
    pods=st.lists(
        st.tuples(
            st.integers(min_value=0, max_value=15),
            st.floats(min_value=0, max_value=4000),
            st.floats(min_value=0, max_value=8000),
            st.booleans(),
        ),
        max_size=64,
    ),
)
def test_discovery_matches_loop_oracle(num_nodes, pods):
    """Vectorized segment-sum == the paper's O(m·p) double loop."""
    pods = [(n % num_nodes, c, m, a) for (n, c, m, a) in pods]
    snap = make_snapshot(
        num_nodes,
        [p[0] for p in pods] or np.zeros((0,), np.int32),
        [p[1] for p in pods] or np.zeros((0,), np.float32),
        [p[2] for p in pods] or np.zeros((0,), np.float32),
        [p[3] for p in pods] or np.zeros((0,), bool),
    )
    rc, rm = discovery.discover(snap)
    for v in range(num_nodes):  # the Go loop, literally
        node_req_cpu = sum(c for (n, c, _, a) in pods if n == v and a)
        node_req_mem = sum(m for (n, _, m, a) in pods if n == v and a)
        assert float(rc[v]) == pytest.approx(8000.0 - node_req_cpu, rel=1e-4, abs=1e-2)
        assert float(rm[v]) == pytest.approx(16000.0 - node_req_mem, rel=1e-4, abs=1e-2)


# ------------------------------------------------------ lifecycle window

def test_window_demand_includes_in_window_only():
    win = TaskWindow(
        t_start=np.array([0.0, 5.0, 14.9, 15.0, 20.0], np.float32),
        cpu=np.array([100, 200, 400, 800, 1600], np.float32),
        mem=np.array([1, 2, 4, 8, 16], np.float32),
        done=np.array([False] * 5),
    )
    # window [5, 15): rows 1, 2 qualify (t=5 in, t=15 out — half-open).
    cpu, mem = lifecycle.window_demand(win, 5.0, 15.0, 1000.0, 10.0)
    assert cpu == pytest.approx(1000 + 200 + 400)
    assert mem == pytest.approx(10 + 2 + 4)


def test_window_demand_skips_done():
    win = TaskWindow(
        t_start=np.array([5.0, 6.0], np.float32),
        cpu=np.array([100, 200], np.float32),
        mem=np.array([1, 2], np.float32),
        done=np.array([True, False]),
    )
    cpu, mem = lifecycle.window_demand(win, 0.0, 10.0, 0.0, 0.0)
    assert cpu == pytest.approx(200)


def test_window_demand_empty_store():
    win = TaskWindow(*(np.zeros((0,), t) for t in (np.float32,) * 3 + (bool,)))
    cpu, mem = lifecycle.window_demand(win, 0.0, 10.0, 123.0, 456.0)
    assert (cpu, mem) == (123.0, 456.0)


@settings(max_examples=100, deadline=None)
@given(
    starts=st.lists(st.floats(min_value=0, max_value=100), min_size=1, max_size=32),
    w0=st.floats(min_value=0, max_value=100),
    dur=st.floats(min_value=0.1, max_value=50),
)
def test_window_demand_matches_oracle(starts, w0, dur):
    n = len(starts)
    cpu_arr = np.arange(1, n + 1, dtype=np.float32) * 10
    mem_arr = np.arange(1, n + 1, dtype=np.float32)
    win = TaskWindow(np.asarray(starts, np.float32), cpu_arr, mem_arr,
                     np.zeros((n,), bool))
    cpu, mem = lifecycle.window_demand(win, w0, w0 + dur, 7.0, 3.0)
    starts32 = np.asarray(starts, np.float32)
    lo, hi = np.float32(w0), np.float32(w0) + np.float32(dur)
    mask = (starts32 >= lo) & (starts32 < hi)
    assert cpu == pytest.approx(7.0 + float(cpu_arr[mask].sum()), rel=1e-5)
    assert mem == pytest.approx(3.0 + float(mem_arr[mask].sum()), rel=1e-5)
