"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Per assignment: for each kernel, sweep shapes/dtypes and assert_allclose
against the ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import flash_decode
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ops import chunked_scan
from repro.kernels.mamba_scan.ref import scan_ref

pytestmark = pytest.mark.slow

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------- flash attention

FA_CASES = [
    # (B, S, T, H, KV, d, causal, window)
    (2, 64, 64, 4, 2, 64, True, None),
    (1, 128, 128, 8, 8, 128, True, None),
    (2, 33, 33, 2, 1, 80, True, None),  # ragged seq + h2o head_dim
    (1, 64, 64, 8, 2, 64, True, 16),  # sliding window
    (2, 16, 50, 4, 4, 32, False, None),  # cross-attention shape
    (1, 256, 256, 14, 2, 64, True, None),  # qwen2 heads
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_matches_ref(case, dtype):
    B, S, T, H, KV, d, causal, window = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = rand(ks[0], (B, S, H, d), dtype)
    k = rand(ks[1], (B, T, KV, d), dtype)
    v = rand(ks[2], (B, T, KV, d), dtype)
    out = flash_attention(q, k, v, causal, window, True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_grads_flow():
    """custom_vjp backward (reference recompute) must produce grads."""
    B, S, H, KV, d = 1, 32, 4, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = rand(ks[0], (B, S, H, d), jnp.float32)
    k = rand(ks[1], (B, S, KV, d), jnp.float32)
    v = rand(ks[2], (B, S, KV, d), jnp.float32)

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, None, True) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for gi in g:
        assert bool(jnp.isfinite(gi).all())
        assert float(jnp.abs(gi).max()) > 0


# ------------------------------------------------------------ mamba scan

MS_CASES = [
    # (B, S, di, n)
    (2, 64, 32, 16),
    (1, 128, 256, 16),
    (2, 96, 48, 8),  # chunk/block fallbacks (96 = 3*32)
    (1, 256, 512, 4),
]


@pytest.mark.parametrize("case", MS_CASES)
def test_mamba_scan_matches_ref(case):
    B, S, di, n = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    # decay-like da in (0, 1), bounded dbx — mirrors exp(dt·A) statistics
    da = jax.random.uniform(ks[0], (B, S, di, n), jnp.float32, 0.5, 0.999)
    dbx = jax.random.normal(ks[1], (B, S, di, n), jnp.float32) * 0.1
    h0 = jax.random.normal(ks[2], (B, di, n), jnp.float32)
    h, hf = chunked_scan(da, dbx, h0, interpret=True)
    h_ref, hf_ref = scan_ref(da, dbx, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_ref),
                               atol=1e-5, rtol=1e-5)


def test_mamba_scan_matches_model_chunked_scan():
    """The model's XLA chunked scan and the kernel agree (same math)."""
    from repro.models.layers import _ssm_scan_chunked

    ks = jax.random.split(jax.random.key(7), 3)
    B, S, di, n = 2, 64, 64, 16
    da = jax.random.uniform(ks[0], (B, S, di, n), jnp.float32, 0.7, 0.99)
    dbx = jax.random.normal(ks[1], (B, S, di, n), jnp.float32) * 0.1
    h0 = jnp.zeros((B, di, n), jnp.float32)
    h1, hf1 = chunked_scan(da, dbx, h0, interpret=True)
    h2, hf2 = _ssm_scan_chunked(da, dbx, h0)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf1), np.asarray(hf2), atol=1e-5)


# ------------------------------------------------------- decode attention

DA_CASES = [
    # (B, T, H, KV, d, pos_mode)
    (2, 128, 4, 2, 64, "full"),
    (1, 256, 8, 8, 128, "partial"),
    (4, 64, 14, 2, 64, "ragged"),  # qwen2 heads, per-seq positions
    (2, 100, 4, 4, 80, "partial"),  # ragged T + h2o head_dim
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DA_CASES)
def test_decode_attention_matches_ref(case, dtype):
    B, T, H, KV, d, pos_mode = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 4)
    q = rand(ks[0], (B, H, d), dtype)
    k = rand(ks[1], (B, T, KV, d), dtype)
    v = rand(ks[2], (B, T, KV, d), dtype)
    if pos_mode == "full":
        pos = jnp.full((B,), T, jnp.int32)
    elif pos_mode == "partial":
        pos = jnp.full((B,), T // 2, jnp.int32)
    else:
        pos = jax.random.randint(ks[3], (B,), 1, T, jnp.int32)
    out = flash_decode(q, k, v, pos, interpret=True)
    ref = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])
