"""Tier discipline: every test belongs to exactly one tier.

CI runs ``-m tier1`` and ``-m slow`` as separate jobs; a test carrying
neither marker (or both) would silently fall out of (or run twice in)
the split, so collection fails loudly instead.
"""
import pytest


def pytest_collection_modifyitems(config, items):
    untiered = []
    for item in items:
        has_tier1 = item.get_closest_marker("tier1") is not None
        has_slow = item.get_closest_marker("slow") is not None
        if has_tier1 == has_slow:  # neither, or both
            untiered.append(item.nodeid)
    if untiered:
        raise pytest.UsageError(
            "tests must carry exactly one tier marker (tier1 xor slow); "
            "offenders: " + ", ".join(sorted(untiered)[:10])
        )
