"""Dry-run machinery integration test on a small (8-device) mesh.

Exercises the real lowering paths (train/prefill/decode with policy
shardings, activation constraints, collective-byte extraction) in a
subprocess so the main test process keeps a single device.
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, jax
from repro.configs import get_smoke_config
from repro.launch.dryrun import (analyse, collective_bytes, cost_dict,
                                 lower_decode, lower_prefill, lower_train)
from repro.models.api import ShapeSpec, build_model
from repro.parallel.act_sharding import activation_sharding
from repro.parallel.policy import ShardingPolicy

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
policy = ShardingPolicy(mesh)

for arch in ("llama3-8b", "olmoe-1b-7b", "falcon-mamba-7b",
             "jamba-1.5-large-398b", "whisper-base"):
    cfg = dataclasses.replace(get_smoke_config(arch), remat=True,
                              scan_layers=True)
    model = build_model(cfg)
    shape_train = ShapeSpec("t", "train", 16, 8)
    shape_pre = ShapeSpec("p", "prefill", 16, 8)
    shape_dec = ShapeSpec("d", "decode", 32, 8)
    with mesh, activation_sharding(policy):
        ct = lower_train(model, policy, shape_train).compile()
        cp = lower_prefill(model, policy, shape_pre).compile()
    with mesh, activation_sharding(policy, serve=True):
        cd = lower_decode(model, policy, shape_dec).compile()
    for name, c in (("train", ct), ("prefill", cp), ("decode", cd)):
        cost = cost_dict(c)
        assert cost.get("flops", 0) > 0, (arch, name)
        mem = c.memory_analysis()
        assert mem.temp_size_in_bytes >= 0
    print(f"{arch}: OK")
print("DRYRUN_OK")
"""


def test_dryrun_small_mesh_all_families():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[256,128]{1,0} all-reduce-start(%y), to_apply=%sum
  %tup = (f32[4,4]{1,0}, f32[8]{0}) all-to-all(%a, %b)
  %cp = u32[32]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %notacoll = f32[2,2]{1,0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 256 * 128 * 4
    assert out["all-to-all"] == 4 * 4 * 4 + 8 * 4
    assert out["collective-permute"] == 32 * 4
