"""The composable Scenario API: registries, typed configs, runner.

Four contracts:

* **registries** — built-ins present with their capability flags,
  third-party entries plug in by decorator and drive a real engine run,
  unknown names fail with actionable messages;
* **typed configs** — ``Scenario``/``EngineConfig`` JSON-round-trip to
  equal dataclasses, ``validate()`` raises actionable errors, and the
  retired flat constructor kwargs stay gone (``TypeError``; ``evolve()``
  is the supported flat spelling);
* **runner** — the paper grid (aras/fcfs × constant/linear/pyramid)
  runs end-to-end through ``run_scenario()``, and a single-kind
  scenario reproduces the legacy ``run_experiment`` bit for bit;
* **results** — ``RunResult`` serializes to schema-stable JSON.
"""
import dataclasses
import json

import pytest

from repro.api import (
    ALLOCATORS,
    ARRIVALS,
    BACKENDS,
    PLACEMENTS,
    AllocatorConfig,
    ClusterConfig,
    EngineConfig,
    Scenario,
    TimingConfig,
    grid,
    run_scenario,
)
from repro.engine import run_experiment
from repro.workflows import arrival

pytestmark = pytest.mark.tier1

FAST_TIMING = TimingConfig(pod_startup_delay=1.0, cleanup_delay=1.0,
                           duration_multiplier=1.0)
FAST = EngineConfig(timing=FAST_TIMING)

SMALL_ARRIVALS = {
    "constant": {"y": 2, "bursts": 2, "interval": 30.0},
    "linear": {"k": 1, "d": 1, "bursts": 2, "interval": 30.0},
    "pyramid": {"start": 1, "peak": 2, "step": 1, "total": 4,
                "interval": 30.0},
}


# ------------------------------------------------------------- registries

def test_builtin_registry_entries():
    assert ALLOCATORS.names() == ("adaptive_scaling", "aras", "fcfs")
    assert "baseline" in ALLOCATORS  # alias
    assert ALLOCATORS.get("baseline").name == "fcfs"
    assert ALLOCATORS.get("aras").supports("adaptive_scaling")
    assert not ALLOCATORS.get("fcfs").supports("adaptive_scaling")

    assert set(PLACEMENTS.names()) == {"worst_fit", "best_fit",
                                       "first_fit", "balanced"}
    assert PLACEMENTS.get("balanced").supports("needs_capacity_view")
    assert not PLACEMENTS.get("worst_fit").supports("needs_capacity_view")

    assert BACKENDS.names() == ("pallas", "scan")
    assert ARRIVALS.names() == ("constant", "jittered", "linear", "poisson",
                                "pyramid", "spike", "trace")
    for name in ("poisson", "jittered", "spike"):
        assert ARRIVALS.get(name).supports("stochastic"), name
    for name in ("constant", "linear", "pyramid", "trace"):
        assert not ARRIVALS.get(name).supports("stochastic"), name
    assert len(list(ALLOCATORS)) == 3


@pytest.mark.parametrize("registry,noun", [
    (ALLOCATORS, "allocator"),
    (PLACEMENTS, "placement policy"),
    (BACKENDS, "alloc backend"),
    (ARRIVALS, "arrival pattern"),
])
def test_unknown_registry_name_is_actionable(registry, noun):
    with pytest.raises(ValueError, match=f"unknown {noun} 'wat'"):
        registry.get("wat")
    # The message lists what IS registered, so a typo is self-serviced.
    with pytest.raises(ValueError, match=registry.names()[0]):
        registry.get("wat")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @PLACEMENTS.register("worst_fit")
        def clash(*a):  # pragma: no cover - never registered
            return a


def test_overwrite_registration_beats_stale_alias():
    """overwrite=True over an alias name must resolve to the new entry."""
    from repro.api import Registry

    reg = Registry("scratch")
    reg.register("real", aliases=("nick",))(lambda: "real")
    assert reg.get("nick").name == "real"

    reg.register("nick", overwrite=True, doc="shadow")(lambda: "nick")
    assert reg.get("nick").name == "nick"  # entry, not the stale alias
    assert reg.get("nick").doc == "shadow"
    assert reg.get("real").name == "real"  # original entry untouched


def test_unregister_alias_removes_only_the_alias():
    from repro.api import Registry

    reg = Registry("scratch")
    reg.register("host", aliases=("alias_a", "alias_b"))(lambda: None)

    reg.unregister("alias_a")  # an alias: only it disappears
    assert "alias_a" not in reg and "alias_b" in reg and "host" in reg
    reg.unregister("host")  # the entry: takes its aliases with it
    assert "host" not in reg and "alias_b" not in reg


def test_custom_placement_policy_plugs_in():
    """A third-party policy drives a real engine run, no core edits."""

    @PLACEMENTS.register("most_free_mem",
                         doc="max residual memory among fitting nodes")
    def _most_free_mem(res_cpu, res_mem, cpu, mem, cap_cpu, cap_mem):
        return res_mem

    try:
        cfg = FAST.evolve(alloc=AllocatorConfig(placement="most_free_mem"))
        m = run_experiment("montage", [(0.0, 2)], "aras", seed=0, config=cfg)
        assert len(m.workflow_durations) == 2
    finally:
        PLACEMENTS.unregister("most_free_mem")
    assert "most_free_mem" not in PLACEMENTS


def test_custom_arrival_pattern_plugs_in():
    @ARRIVALS.register("front_loaded", doc="everything at t=0")
    def _front_loaded(total=4):
        return [(0.0, total)]

    try:
        sc = Scenario(workflows=("montage",), arrival="front_loaded",
                      arrival_params={"total": 2}, engine=FAST)
        result = run_scenario(sc)
        assert result.num_workflows == 2
    finally:
        ARRIVALS.unregister("front_loaded")


# ------------------------------------------------------------ round trips

def test_engine_config_json_round_trip():
    cfg = EngineConfig(
        cluster=ClusterConfig(num_nodes=12, node_cpu=8000.0,
                              node_mem=16000.0, num_clusters=3,
                              sharding="off"),
        alloc=AllocatorConfig(algorithm="fcfs", placement="best_fit",
                              backend="scan", batch_allocation=False),
        timing=TimingConfig(pod_startup_delay=2.0, max_time=1e5),
        invariant_checks=False,
    )
    again = EngineConfig.from_json(cfg.to_json())
    assert again == cfg
    assert json.loads(cfg.to_json())["cluster"]["num_clusters"] == 3


def test_scenario_json_round_trip():
    sc = Scenario(
        name="rt", workflows=("ligo", "montage"), arrival="pyramid",
        arrival_params={"start": 1, "peak": 3, "step": 1, "total": 6},
        engine=FAST.evolve(allocator="fcfs"),
        seed=7, task_kwargs={"mem": 2600.0, "min_mem": 200.0},
    )
    again = Scenario.from_json(sc.to_json())
    assert again == sc
    assert again.engine is not None and again.engine == sc.engine
    # Defaults survive a sparse dict too.
    sparse = Scenario.from_dict({"name": "sparse"})
    assert sparse.workflows == ("ligo",) and sparse.engine == EngineConfig()


# -------------------------------------------------------------- validate()

@pytest.mark.parametrize("bad,match", [
    (dict(cluster=ClusterConfig(num_nodes=0)), "num_nodes"),
    (dict(cluster=ClusterConfig(num_nodes=4, node_cpu=-1.0)), "node_cpu"),
    (dict(cluster=ClusterConfig(num_nodes=3, num_clusters=4)),
     "num_clusters"),
    (dict(cluster=ClusterConfig(sharding="wat")), "cluster_sharding"),
    (dict(alloc=AllocatorConfig(algorithm="wat")), "unknown allocator"),
    (dict(alloc=AllocatorConfig(placement="wat")),
     "unknown placement policy"),
    (dict(alloc=AllocatorConfig(backend="cuda")), "unknown alloc backend"),
    (dict(alloc=AllocatorConfig(alpha=0.0)), "alpha"),
    (dict(alloc=AllocatorConfig(beta=-1.0)), "beta"),
    (dict(timing=TimingConfig(pod_startup_delay=-1.0)),
     "pod_startup_delay"),
    (dict(timing=TimingConfig(oom_fraction=1.5)), "oom_fraction"),
    (dict(timing=TimingConfig(duration_multiplier=0.0)),
     "duration_multiplier"),
])
def test_validate_raises_actionable_errors(bad, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**bad).validate()


def test_scenario_validate_errors():
    with pytest.raises(ValueError, match="workflow kind"):
        Scenario(workflows=("wat",)).validate()
    with pytest.raises(ValueError, match="at least one"):
        Scenario(workflows=()).validate()
    with pytest.raises(ValueError, match="unknown arrival pattern"):
        Scenario(arrival="wat").validate()
    with pytest.raises(ValueError, match="arrival_params"):
        Scenario(arrival="constant",
                 arrival_params={"nope": 1}).validate()
    assert Scenario().validate() is not None


def test_unknown_flat_kwarg_is_a_type_error():
    with pytest.raises(TypeError, match="unexpected keyword"):
        EngineConfig(num_noodles=3)


def test_flat_constructor_kwargs_are_retired():
    """The deprecated flat-kwarg shim completed its cycle: flat names
    are constructor TypeErrors now; ``evolve()`` keeps the flat
    spelling."""
    with pytest.raises(TypeError, match="unexpected keyword"):
        EngineConfig(num_nodes=64)
    with pytest.raises(TypeError, match="unexpected keyword"):
        EngineConfig(allocator="fcfs", alpha=0.5)
    evolved = EngineConfig().evolve(num_nodes=64, allocator="fcfs")
    assert evolved.cluster.num_nodes == 64
    assert evolved.alloc.algorithm == "fcfs"


def test_from_dict_rejects_unknown_keys():
    """A typo'd or legacy-flat serialized config must not silently
    deserialize to defaults."""
    with pytest.raises(ValueError, match="unknown EngineConfig field"):
        EngineConfig.from_dict({"num_nodes": 64})
    with pytest.raises(ValueError, match="aloc"):
        EngineConfig.from_dict({"aloc": {"algorithm": "fcfs"}})
    with pytest.raises(TypeError):  # unknown key inside a sub-config
        EngineConfig.from_dict({"cluster": {"num_noodles": 3}})


# ------------------------------------------------------ the paper grid

@pytest.mark.parametrize("arrival_name", sorted(SMALL_ARRIVALS))
@pytest.mark.parametrize("algorithm", ("aras", "fcfs"))
def test_paper_grid_end_to_end(algorithm, arrival_name):
    """aras/fcfs × constant/linear/pyramid through run_scenario, and
    bit-for-bit parity with the legacy run_experiment wiring."""
    params = SMALL_ARRIVALS[arrival_name]
    sc = Scenario(
        name=f"grid-{algorithm}-{arrival_name}",
        workflows=("montage",),
        arrival=arrival_name,
        arrival_params=params,
        engine=FAST.evolve(allocator=algorithm),
    )
    result = run_scenario(sc)
    expected_n = sum(n for _, n in sc.pattern())
    assert result.num_workflows == expected_n
    assert result.avg_total_duration > 0
    assert 0.0 <= result.cpu_usage_rate <= 1.0
    assert 0.0 <= result.mem_usage_rate <= 1.0

    legacy_pattern = getattr(arrival, arrival_name)(**params)
    legacy = run_experiment("montage", legacy_pattern, algorithm, seed=0,
                            config=FAST)
    assert result.metrics.makespan == legacy.makespan
    assert result.metrics.alloc_trace == legacy.alloc_trace
    assert result.metrics.workflow_durations == legacy.workflow_durations
    assert result.metrics.oom_events == legacy.oom_events


def test_grid_builder_covers_the_sweep():
    sweep = grid(Scenario(name="paper", engine=FAST))
    assert len(sweep) == 6  # 2 allocators × 3 arrival patterns
    names = {s.name for s in sweep}
    assert "paper-aras-constant" in names and "paper-fcfs-pyramid" in names
    algos = {s.engine.alloc.algorithm for s in sweep}
    assert algos == {"aras", "fcfs"}


def test_multi_kind_scenario_cycles_workflow_set():
    sc = Scenario(workflows=("montage", "ligo"), arrival="constant",
                  arrival_params={"y": 2, "bursts": 1}, engine=FAST)
    result = run_scenario(sc)
    assert result.num_workflows == 2
    kinds = {w.split("-")[0] for w in result.metrics.workflow_durations}
    assert kinds == {"montage", "ligo"}


def test_run_result_json_schema():
    sc = Scenario(workflows=("montage",), arrival="constant",
                  arrival_params={"y": 1, "bursts": 1}, engine=FAST)
    payload = json.loads(run_scenario(sc).to_json())
    for key in ("scenario", "avg_total_duration", "avg_workflow_duration",
                "cpu_usage_rate", "mem_usage_rate",
                "per_decision_latency_us", "num_workflows",
                "num_allocations", "num_waits", "num_oom_events",
                "num_reallocations", "num_dispatches", "mean_burst_width",
                "sla_violation_rate", "wall_time_s"):
        assert key in payload, key
    assert "metrics" not in payload  # trace object stays out of the JSON
    assert payload["scenario"]["engine"]["alloc"]["algorithm"] == "aras"
