"""Layer-level unit tests (MoE routing, RoPE, norms, scan).

Property-based (hypothesis) companions live in
``tests/property/test_layers_props.py`` so this module collects on a
bare jax+pytest environment.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.config import ModelConfig, MoEConfig

pytestmark = pytest.mark.slow


def moe_cfg(dispatch="scatter", cf=1.25, k=2, E=8, shared=0):
    return ModelConfig(
        name="t", num_layers=1, d_model=32, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=128,
        moe=MoEConfig(num_experts=E, top_k=k, expert_d_ff=48,
                      num_shared_experts=shared, capacity_factor=cf,
                      dispatch_mode=dispatch))


# ------------------------------------------------------------------ MoE

def test_moe_capacity_drops_tokens():
    """Tiny capacity factor must drop routing pairs (and report it)."""
    cfg = moe_cfg(cf=0.1)
    p = L.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32))
    _, aux = L.moe(p, cfg, x)
    assert float(aux.dropped_fraction) > 0.3


def test_moe_dropless_never_drops():
    cfg = moe_cfg(cf=0.01)
    p = L.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32))
    _, aux = L.moe(p, cfg, x, dropless=True)
    assert float(aux.dropped_fraction) == 0.0


def test_scatter_equals_einsum_dispatch_smoke():
    """The two dispatch modes are the same function (spot check; the
    property form lives in tests/property/test_layers_props.py)."""
    cfg_e = moe_cfg("einsum", cf=1.25, k=2)
    cfg_s = moe_cfg("scatter", cf=1.25, k=2)
    p = L.init_moe(jax.random.key(0), cfg_e)
    x = jax.random.normal(jax.random.key(7), (2, 16, 32))
    ye, auxe = L.moe(p, cfg_e, x)
    ys, auxs = L.moe(p, cfg_s, x)
    np.testing.assert_allclose(np.asarray(ye), np.asarray(ys),
                               atol=1e-4, rtol=1e-4)
    assert abs(float(auxe.dropped_fraction) -
               float(auxs.dropped_fraction)) < 1e-6


def test_moe_shared_experts_always_contribute():
    cfg = moe_cfg(shared=1, cf=0.01)  # everything dropped except shared
    p = L.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, 32))
    y, aux = L.moe(p, cfg, x)
    shared_only = L.mlp(p["shared"], x.reshape(16, 32)).reshape(1, 16, 32)
    # with near-total dropping, output ≈ shared expert path
    corr = float(jnp.sum(y * shared_only) /
                 (jnp.linalg.norm(y) * jnp.linalg.norm(shared_only)))
    assert corr > 0.9


# ------------------------------------------------------------ RoPE/norm

def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.key(0), (2, 8, 4, 64))
    y = L.apply_rope(x, jnp.arange(8)[None], 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<RoPE(q,m), RoPE(k,n)> depends only on m-n."""
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 32))

    def dot(m, n):
        qm = L.apply_rope(q, jnp.array([[m]]), 10000.0)
        kn = L.apply_rope(k, jnp.array([[n]]), 10000.0)
        return float(jnp.sum(qm * kn))

    assert dot(3, 5) == pytest.approx(dot(10, 12), rel=1e-4)
    assert dot(0, 4) == pytest.approx(dot(7, 11), rel=1e-4)


def test_rmsnorm_scale_invariant_direction():
    x = jax.random.normal(jax.random.key(3), (4, 32))
    p = L.init_rmsnorm(32)
    y1 = L.rmsnorm(p, x)
    y2 = L.rmsnorm(p, 7.0 * x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


# --------------------------------------------------------------- scan

def test_scan_or_unroll_equivalence():
    xs = {"w": jax.random.normal(jax.random.key(4), (5, 8, 8))}

    def body(c, p):
        c = jnp.tanh(c @ p["w"])
        return c, jnp.sum(c)

    c0 = jax.random.normal(jax.random.key(5), (2, 8))
    c1, y1 = L.scan_or_unroll(body, c0, xs, use_scan=True)
    c2, y2 = L.scan_or_unroll(body, c0, xs, use_scan=False)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_ssm_chunked_scan_matches_sequential():
    B, S, di, n = 2, 50, 16, 4
    ks = jax.random.split(jax.random.key(6), 3)
    da = jax.random.uniform(ks[0], (B, S, di, n), jnp.float32, 0.6, 0.99)
    dbx = jax.random.normal(ks[1], (B, S, di, n)) * 0.1
    h0 = jax.random.normal(ks[2], (B, di, n))
    h_c, hf_c = L._ssm_scan_chunked(da, dbx, h0, chunk=16)

    h = h0
    outs = []
    for t in range(S):
        h = da[:, t] * h + dbx[:, t]
        outs.append(h)
    h_ref = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_ref),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hf_c), np.asarray(h_ref[:, -1]),
                               atol=1e-5, rtol=1e-4)
