"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.api import build_model
from repro.serving.engine import ServeConfig, ServeEngine

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def served():
    cfg = get_smoke_config("llama3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def greedy_reference(model, params, prompt, n_new, max_len):
    """Sequential single-request greedy decode via prefill+decode_step."""
    toks = list(map(int, prompt))
    batch = {"tokens": jnp.asarray([toks], jnp.int32)}
    logits, cache = model.prefill(params, batch, max_len=max_len)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_single_request_matches_reference(served):
    cfg, model, params = served
    prompt = np.array([5, 9, 2, 71, 33], np.int32)
    eng = ServeEngine(model, params, ServeConfig(n_slots=2, max_len=32))
    rid = eng.submit(prompt, max_new_tokens=6)
    done = eng.run_to_completion()
    ref = greedy_reference(model, params, prompt, 6, 32)
    assert done[rid] == ref


def test_continuous_batching_matches_isolated(served):
    """Concurrent requests must each decode as if they were alone."""
    cfg, model, params = served
    prompts = [np.array(p, np.int32) for p in
               ([1, 2, 3], [10, 20, 30, 40], [7], [100, 90, 80, 70, 60])]
    eng = ServeEngine(model, params, ServeConfig(n_slots=2, max_len=32))
    rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    done = eng.run_to_completion()
    assert set(done) == set(rids)
    for rid, p in zip(rids, prompts):
        ref = greedy_reference(model, params, p, 5, 32)
        assert done[rid] == ref, f"request {rid}"


def test_queue_drains_with_fewer_slots_than_requests(served):
    cfg, model, params = served
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_len=32))
    rids = [eng.submit(np.array([i + 1, i + 2], np.int32),
                       max_new_tokens=3) for i in range(5)]
    done = eng.run_to_completion()
    assert set(done) == set(rids)
    assert all(len(v) == 3 for v in done.values())
