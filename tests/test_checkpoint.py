"""Checkpoint store: atomicity, async, retention, elastic resharding."""
import os
import shutil
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)

pytestmark = pytest.mark.slow


@pytest.fixture
def tmpdir(tmp_path):
    return str(tmp_path / "ckpt")


def tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmpdir):
    t = tree()
    save_pytree(t, tmpdir, 7)
    out = restore_pytree(t, tmpdir, 7)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_tree_mismatch_rejected(tmpdir):
    t = tree()
    save_pytree(t, tmpdir, 1)
    bad = {"params": {"w": t["params"]["w"]}, "step": t["step"]}
    with pytest.raises(ValueError, match="mismatch"):
        restore_pytree(bad, tmpdir, 1)


def test_latest_and_gc(tmpdir):
    mgr = CheckpointManager(tmpdir, keep=2)
    t = tree()
    for s in (10, 20, 30):
        mgr.save(t, s, blocking=True)
    assert latest_step(tmpdir) == 30
    kept = sorted(os.listdir(tmpdir))
    assert kept == ["step_00000020", "step_00000030"]


def test_async_save_then_restore(tmpdir):
    mgr = CheckpointManager(tmpdir, keep=3)
    t = tree()
    mgr.save(t, 5, blocking=False)
    got = mgr.restore_latest(t)
    assert got is not None and got[0] == 5


def test_tmp_dirs_never_restored(tmpdir):
    os.makedirs(os.path.join(tmpdir, "step_00000099.tmp"))
    assert latest_step(tmpdir) is None


_ELASTIC_PROG = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_pytree, restore_pytree

d = sys.argv[1]
# "save" on a 4-device (2x2) mesh
mesh4 = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
w = jnp.arange(64 * 64, dtype=jnp.float32).reshape(64, 64)
sharded = jax.device_put(w, NamedSharding(mesh4, P("data", "model")))
save_pytree({"w": sharded}, d, 1)

# restore onto an 8-device (4x2) mesh — elastic scale-up
mesh8 = jax.make_mesh((4, 2), ("data", "model"))
sh = lambda path: NamedSharding(mesh8, P("data", "model"))
out = restore_pytree({"w": jax.ShapeDtypeStruct((64, 64), jnp.float32)},
                     d, 1, sharding_fn=sh)
assert out["w"].sharding.mesh.shape["data"] == 4
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
print("ELASTIC_OK")
"""


def test_elastic_reshard(tmpdir):
    """Checkpoint written under a 4-chip mesh restores onto 8 chips."""
    r = subprocess.run(
        [sys.executable, "-c", _ELASTIC_PROG, tmpdir],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
