"""Pallas alloc-scan kernel ≡ the ``lax.scan`` reference, bit for bit.

Array-level parity over random bursts (all four placement policies, both
allocator modes, head-of-line pending rows, padding rows), plus an
engine-level end-to-end check that a full simulation driven through the
Pallas backend (interpret mode off-TPU) reproduces the scan backend's
metrics exactly.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.allocator import _burst_precompute, _core_dispatch
from repro.core.placement import PLACEMENT_POLICIES
from repro.engine import EngineConfig, TimingConfig, run_experiment

pytestmark = pytest.mark.tier1

FAST = EngineConfig(timing=TimingConfig(
    pod_startup_delay=1.0, cleanup_delay=1.0, duration_multiplier=1.0))


def _random_burst(seed, m=37, num_rec=16, num_rows=8):
    rng = np.random.default_rng(seed)
    res_cpu = rng.uniform(0, 8000, m).astype(np.float32)
    res_mem = rng.uniform(0, 16000, m).astype(np.float32)
    cap_cpu = np.full((m,), 8000.0, np.float32)
    cap_mem = np.full((m,), 16000.0, np.float32)
    rec_t = rng.uniform(0, 50, num_rec).astype(np.float32)
    rec_cpu = rng.uniform(0, 4000, num_rec).astype(np.float32)
    rec_mem = rng.uniform(0, 8000, num_rec).astype(np.float32)
    rec_done = rng.random(num_rec) < 0.3
    b_cpu = rng.uniform(100, 6000, num_rows).astype(np.float32)
    b_mem = rng.uniform(100, 12000, num_rows).astype(np.float32)
    b_min_cpu = (b_cpu * rng.uniform(0.1, 0.9, num_rows)).astype(np.float32)
    b_min_mem = (b_mem * rng.uniform(0.1, 0.9, num_rows)).astype(np.float32)
    b_wend = rng.uniform(0, 40, num_rows).astype(np.float32)
    slots = rng.permutation(num_rec)[:num_rows].astype(np.int32)
    slots[rng.random(num_rows) < 0.25] = -1
    b_attempt = rng.random(num_rows) < 0.9
    b_pending = rng.random(num_rows) < 0.4
    now = np.float32(10.0)
    return (res_cpu, res_mem, cap_cpu, cap_mem, rec_t, rec_cpu, rec_mem,
            rec_done, b_cpu, b_mem, b_min_cpu, b_min_mem, b_wend, slots,
            b_attempt, b_pending, now)


def _run_backend(case, policy, mode, backend):
    (res_cpu, res_mem, cap_cpu, cap_mem, rec_t, rec_cpu, rec_mem, rec_done,
     b_cpu, b_mem, b_min_cpu, b_min_mem, b_wend, slots, b_attempt,
     b_pending, now) = [jnp.asarray(x) for x in case]
    pre = _burst_precompute(
        res_cpu, res_mem, cap_cpu, cap_mem, rec_t, rec_cpu, rec_mem,
        rec_done, b_cpu, b_mem, b_wend, slots, now, mode=mode,
    )
    rc2, rm2, cc2, cm2, tot_c, tot_m, base_c, base_m, dlt_c, dlt_m = pre
    return _core_dispatch(
        rc2, rm2, cc2, cm2, tot_c, tot_m,
        b_cpu, b_mem, b_min_cpu, b_min_mem, base_c, base_m, dlt_c, dlt_m,
        slots, b_attempt, b_pending,
        alpha=0.8, beta=20.0, policy=policy, mode=mode, backend=backend,
    )


@pytest.mark.parametrize("policy", PLACEMENT_POLICIES)
@pytest.mark.parametrize("mode", ["aras", "fcfs"])
def test_kernel_matches_scan_ref(policy, mode):
    for seed in range(3):
        case = _random_burst(seed)
        ref = _run_backend(case, policy, mode, "scan")
        ker = _run_backend(case, policy, mode, "pallas")
        for name, a, b in zip(
                ("cpu", "mem", "node", "accept", "attempted", "scenario"),
                ref, ker):
            a, b = np.asarray(a), np.asarray(b)
            assert a.dtype.kind == b.dtype.kind, name
            assert (a == b).all(), (policy, mode, seed, name, a, b)


@pytest.mark.parametrize("allocator", ["aras", "fcfs"])
def test_engine_end_to_end_kernel_parity(allocator):
    """Full simulation through the Pallas backend ≡ the scan backend."""
    for policy in PLACEMENT_POLICIES:
        runs = {}
        for backend in ("scan", "pallas"):
            cfg = FAST.evolve(placement=policy, alloc_backend=backend)
            runs[backend] = run_experiment("montage", [(0.0, 2)], allocator,
                                           seed=0, config=cfg)
        scan, pallas = runs["scan"], runs["pallas"]
        assert scan.alloc_trace == pallas.alloc_trace, (allocator, policy)
        assert scan.makespan == pallas.makespan
        assert scan.workflow_durations == pallas.workflow_durations
        assert scan.oom_events == pallas.oom_events


def test_unknown_backend_raises():
    from repro.kernels.alloc_scan import resolve_backend
    with pytest.raises(ValueError, match="unknown alloc backend"):
        resolve_backend("cuda")
    assert resolve_backend("scan") == "scan"
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("auto") in ("scan", "pallas")
