"""Fault injection & graceful degradation (``repro.chaos`` + engine).

Three layers of contract:

* **Schedules are data.**  ``FAULTS`` builders return deterministic,
  time-sorted :class:`FaultEvent` lists from ``(num_nodes, seed)`` —
  the same config replays the same faults bit for bit.
* **No lost pods.**  Every task displaced by a node failure either
  re-enters admission through HEAL and recovers, or belongs to a
  workflow terminally counted ``FAILED`` (bounded retry budget /
  deadline) — never silently dropped.  Chaos runs repeat bit-identically
  under a fixed seed.
* **Bounded overload.**  The graceful-degradation knobs
  (``max_retries``, ``backoff_base``, ``workflow_timeout``) turn
  infinite retry into a terminal ``FAILED`` outcome, and the stream
  pump's ``max_pending`` bound turns unbounded queue growth into
  measured shed/defer counts.
"""
import dataclasses

import pytest

from repro.api import (
    FAULTS,
    EngineConfig,
    FaultConfig,
    Scenario,
    TimingConfig,
    run_scenario,
)
from repro.chaos import FaultEvent, node_crash, node_flap, oom_storm
from repro.engine import KubeAdaptor
from repro.engine.events import EventKind
from repro.serving import StreamEngine
from repro.workflows.spec import TaskSpec, WorkflowSpec

pytestmark = pytest.mark.tier1


def _chain_wf(i: int, n_tasks: int = 2, duration: float = 6.0,
              cpu: float = 600.0) -> WorkflowSpec:
    tasks = {
        f"t{j}": TaskSpec(task_id=f"t{j}", image="img", cpu=cpu,
                          mem=2.0 * cpu, duration=duration + j,
                          min_cpu=cpu / 6.0, min_mem=cpu / 3.0)
        for j in range(n_tasks)
    }
    edges = [(f"t{j}", f"t{j + 1}") for j in range(n_tasks - 1)]
    return WorkflowSpec(workflow_id=f"w{i}", tasks=tasks, edges=edges)


_ARRIVALS = [(0.0, _chain_wf(0)), (0.5, _chain_wf(1, n_tasks=1)),
             (4.0, _chain_wf(2, duration=2.0)), (4.2, _chain_wf(3)),
             (11.0, _chain_wf(4, n_tasks=3, cpu=900.0))]


def _run(faults: FaultConfig, num_nodes: int = 10,
         arrivals=None) -> KubeAdaptor:
    eng = KubeAdaptor(EngineConfig(
        timing=TimingConfig(pod_startup_delay=1.0, cleanup_delay=1.0,
                            duration_multiplier=1.0, batch_window=3.0),
        faults=faults,
    ).evolve(num_nodes=num_nodes))
    for t, wf in (arrivals or _ARRIVALS):
        eng.submit(wf, t)
    eng.run()
    return eng


# ------------------------------------------------------- schedules as data

def test_faults_registry_has_builtin_schedules():
    assert {"none", "node_crash", "node_flap", "oom_storm"} <= set(
        FAULTS.names())
    assert FAULTS.get("none").factory() == []
    assert FAULTS.get("node_crash").supports("seeded")


def test_node_crash_is_seed_deterministic():
    a = node_crash(num_nodes=64, nodes=3, at=10.0, seed=5)
    assert a == node_crash(num_nodes=64, nodes=3, at=10.0, seed=5)
    assert len(a) == 3
    assert all(isinstance(e, FaultEvent) and e.kind is EventKind.NODE_DOWN
               and e.t == 10.0 for e in a)
    victims = [e.payload[0] for e in a]
    assert victims == sorted(set(victims))  # distinct, sorted
    assert a != node_crash(num_nodes=64, nodes=3, at=10.0, seed=6)


def test_node_flap_pairs_and_validation():
    ev = node_flap(num_nodes=8, nodes=2, at=5.0, down_for=3.0,
                   repeats=2, period=20.0, seed=1)
    assert len(ev) == 8  # 2 nodes x 2 repeats x (down + up)
    assert [e.t for e in ev] == sorted(e.t for e in ev)
    downs = [e for e in ev if e.kind is EventKind.NODE_DOWN]
    ups = [e for e in ev if e.kind is EventKind.NODE_UP]
    assert len(downs) == len(ups) == 4
    assert {e.payload for e in downs} == {e.payload for e in ups}
    with pytest.raises(ValueError, match="shorter than"):
        node_flap(num_nodes=8, down_for=30.0, repeats=2, period=20.0)


def test_oom_storm_schedule():
    ev = oom_storm(num_nodes=8, at=7.0, victims=3, repeats=2, period=10.0)
    assert [e.t for e in ev] == [7.0, 17.0]
    assert all(e.kind is EventKind.OOM_STORM and e.payload == (3,)
               for e in ev)
    with pytest.raises(ValueError, match="victims"):
        oom_storm(num_nodes=8, victims=0)


def test_fault_config_validation_and_round_trip():
    cfg = EngineConfig().evolve(
        fault_schedule="node_crash", fault_params={"at": 9.0, "nodes": 2},
        fault_seed=3, max_retries=4, backoff_base=2.0, workflow_timeout=500.0)
    assert cfg.faults.schedule == "node_crash"
    assert EngineConfig.from_json(cfg.to_json()) == cfg
    with pytest.raises(ValueError, match="unknown fault schedule"):
        FaultConfig(schedule="nope").validate()
    with pytest.raises(ValueError, match="node_crash"):
        FaultConfig(schedule="node_crash",
                    params={"bogus": 1}).validate()
    with pytest.raises(ValueError, match="max_retries"):
        FaultConfig(max_retries=-1).validate()
    with pytest.raises(ValueError, match="backoff_factor"):
        FaultConfig(backoff_factor=0.5).validate()
    with pytest.raises(ValueError, match="workflow_timeout"):
        FaultConfig(workflow_timeout=0.0).validate()


# ------------------------------------------------- engine: no lost pods

def _assert_no_lost_pods(eng: KubeAdaptor) -> None:
    m = eng.metrics
    recovered = {key for key, _ in m.recovery_times}
    failed_wfs = {wf for _, wf, _ in m.failed_workflows}
    for _, key in m.displaced_tasks:
        assert key in recovered or key.split("/")[0] in failed_wfs, key


def test_node_crash_heals_every_displaced_task():
    eng = _run(FaultConfig(schedule="node_crash",
                           params={"at": 5.0, "nodes": 3}, seed=2))
    m = eng.metrics
    assert [(t, n, w) for t, n, w in m.node_events
            if w == "down"], "crash never fired"
    assert m.num_displaced > 0
    assert m.num_recovered == m.num_displaced  # ample spare capacity
    assert m.mean_time_to_recovery > 0.0
    assert not m.failed_workflows and not m.failed_tasks
    _assert_no_lost_pods(eng)
    assert len(m.workflow_durations) == len(_ARRIVALS)  # all complete
    assert eng.cluster.offline_nodes == sorted(
        n for _, n, w in m.node_events if w == "down")


def test_chaos_runs_are_bit_identical():
    faults = FaultConfig(schedule="node_flap",
                         params={"at": 3.0, "down_for": 6.0, "nodes": 2},
                         seed=7)
    a, b = _run(faults).metrics, _run(faults).metrics
    assert a.alloc_trace == b.alloc_trace
    assert a.makespan == b.makespan
    assert a.node_events == b.node_events
    assert a.displaced_tasks == b.displaced_tasks
    assert a.recovery_times == b.recovery_times
    assert a.usage_series == b.usage_series


def test_node_flap_restores_capacity():
    eng = _run(FaultConfig(schedule="node_flap",
                           params={"at": 3.0, "down_for": 6.0, "nodes": 2}))
    m = eng.metrics
    downs = [n for _, n, w in m.node_events if w == "down"]
    ups = [n for _, n, w in m.node_events if w == "up"]
    assert sorted(downs) == sorted(ups)
    assert eng.cluster.offline_nodes == []
    assert len(m.workflow_durations) == len(_ARRIVALS)
    _assert_no_lost_pods(eng)


def test_oom_storm_self_heals():
    eng = _run(FaultConfig(schedule="oom_storm",
                           params={"at": 4.0, "victims": 2}))
    m = eng.metrics
    assert len(m.oom_events) >= 2
    assert len(m.workflow_durations) == len(_ARRIVALS)
    eng.cluster.check_invariants()


# --------------------------------------- graceful degradation knobs

def _oversized_wf(i: int) -> WorkflowSpec:
    # min_cpu larger than any node: admission can never succeed.
    return WorkflowSpec(workflow_id=f"big{i}", tasks={
        "t0": TaskSpec(task_id="t0", image="img", cpu=10_000.0,
                       mem=20_000.0, duration=5.0, min_cpu=9_000.0,
                       min_mem=18_000.0)}, edges=[])


def test_retry_budget_fails_workflow_terminally():
    eng = _run(FaultConfig(max_retries=2, workflow_timeout=400.0),
               arrivals=[(0.0, _chain_wf(0)), (1.0, _oversized_wf(0))])
    m = eng.metrics
    reasons = {wf: why for _, wf, why in m.failed_workflows}
    assert reasons.get("big0") == "retry_budget"
    assert any(key.startswith("big0/") for _, key in m.failed_tasks)
    assert len(m.workflow_durations) == 1  # w0 still completes


def test_workflow_deadline_fails_stragglers():
    eng = _run(FaultConfig(workflow_timeout=2.0),
               arrivals=[(0.0, _oversized_wf(0))])
    m = eng.metrics
    assert [(wf, why) for _, wf, why in m.failed_workflows] \
        == [("big0", "deadline")]
    assert m.makespan <= 2.0 + 1e-9


def test_backoff_gates_retry_churn():
    """Exponential backoff must reduce futile admission attempts on a
    saturated cluster without changing what eventually completes."""
    arrivals = [(float(i) * 0.25, _chain_wf(i)) for i in range(12)]
    plain = _run(FaultConfig(), num_nodes=2, arrivals=arrivals).metrics
    backed = _run(FaultConfig(backoff_base=4.0, backoff_factor=2.0),
                  num_nodes=2, arrivals=arrivals).metrics
    assert len(plain.workflow_durations) == len(arrivals)
    assert len(backed.workflow_durations) == len(arrivals)
    assert backed.num_waits <= plain.num_waits


# ------------------------------------------------ stream backpressure

def _overload_arrivals(n: int = 40):
    # Long-running, fat tasks: two nodes saturate well before the
    # arrival burst ends, so admission genuinely backs up.
    return [(float(i) * 0.1, _chain_wf(i, n_tasks=1, duration=30.0,
                                       cpu=3000.0)) for i in range(n)]


def _stream_engine(num_nodes: int = 2) -> KubeAdaptor:
    return KubeAdaptor(EngineConfig(
        timing=TimingConfig(pod_startup_delay=1.0, cleanup_delay=1.0,
                            duration_multiplier=1.0, batch_window=3.0),
    ).evolve(num_nodes=num_nodes))


def test_stream_shed_bounds_admission():
    arrivals = _overload_arrivals()
    stats = StreamEngine(_stream_engine(), arrivals, max_pending=4,
                         overload_policy="shed").serve()
    assert stats.shed_workflows > 0
    assert stats.deferred_workflows == 0
    done = len(stats.metrics.workflow_durations)
    assert done == len(arrivals) - stats.shed_workflows  # shed, not lost
    assert stats.to_dict()["shed_workflows"] == stats.shed_workflows


def test_stream_defer_completes_everything():
    arrivals = _overload_arrivals()
    stats = StreamEngine(_stream_engine(), arrivals, max_pending=4,
                         overload_policy="defer").serve()
    assert stats.deferred_workflows > 0
    assert stats.shed_workflows == 0
    assert len(stats.metrics.workflow_durations) == len(arrivals)


def test_stream_rejects_bad_admission_params():
    eng = _stream_engine()
    with pytest.raises(ValueError, match="overload_policy"):
        StreamEngine(eng, [], overload_policy="panic")
    with pytest.raises(ValueError, match="max_pending"):
        StreamEngine(eng, [], max_pending=-1)


# ------------------------------------------------- scenario integration

def test_scenario_chaos_counters_and_determinism():
    sc = Scenario(
        name="chaos", workflows=("montage",), arrival="constant",
        arrival_params={"y": 2, "bursts": 2, "interval": 60.0},
        engine=EngineConfig(
            timing=TimingConfig(batch_window=5.0),
        ).evolve(num_nodes=8, fault_schedule="node_crash",
                 fault_params={"at": 30.0, "nodes": 2}, fault_seed=4),
        seed=3)
    a, b = run_scenario(sc), run_scenario(sc)
    assert a.num_displaced > 0
    failed_wfs = {wf for _, wf, _ in a.metrics.failed_workflows}
    assert a.num_displaced == a.num_recovered + sum(
        1 for _, key in a.metrics.displaced_tasks
        if key.split("/")[0] in failed_wfs)
    assert a.metrics.alloc_trace == b.metrics.alloc_trace
    assert a.num_displaced == b.num_displaced
    assert a.mean_time_to_recovery == b.mean_time_to_recovery
    assert dataclasses.asdict(a.metrics)["node_events"] \
        == dataclasses.asdict(b.metrics)["node_events"]


def test_scenario_stream_backpressure_round_trip():
    sc = Scenario(
        name="bp", workflows=("montage",), arrival="spike",
        arrival_params={"lam": 8, "bursts": 2, "interval": 60.0},
        engine=EngineConfig(
            timing=TimingConfig(batch_window=10.0)).evolve(num_nodes=4),
        seed=1, stream=True,
        stream_params={"max_pending": 6, "overload_policy": "shed"})
    assert Scenario.from_json(sc.to_json()) == sc
    res = run_scenario(sc)
    assert res.shed_workflows > 0
    assert res.decisions_per_sec > 0.0
    with pytest.raises(ValueError, match="stream"):
        dataclasses.replace(sc, stream=False).validate()
