"""Per-architecture smoke tests: reduced config, one forward + one train
step + one prefill/decode round-trip on CPU.  Asserts output shapes and
no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.api import build_model, make_batch

pytestmark = pytest.mark.slow

BATCH, SEQ = 2, 16


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            model = build_model(cfg)
            params = model.init(jax.random.key(0))
            batch = make_batch(cfg, BATCH, SEQ, jax.random.key(1))
            cache[arch] = (cfg, model, params, batch)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(built, arch):
    cfg, model, params, batch = built(arch)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.padded_vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(built, arch):
    """One SGD step on one batch must reduce the loss (sanity of grads)."""
    cfg, model, params, batch = built(arch)
    loss_fn = lambda p: model.loss(p, batch)[0]
    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(l0)), arch
    for g in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(g).all()), f"{arch}: non-finite grad"
    # normalized step along -grad: loss must decrease (directional deriv.)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    lr = 0.05 / (float(gnorm) + 1e-9)
    params2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0), f"{arch}: loss {l0} -> {l1}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(built, arch):
    """Teacher-forced decode must reproduce forward logits (cache parity).

    Mamba-bearing archs accumulate bf16 associativity noise between the
    chunked-scan prefill and the sequential decode recurrence (verified
    ~3e-6 in fp32 by test_decode_parity_fp32), so they get a wider band.
    """
    cfg, model, params, batch = built(arch)
    tol = 0.15 if cfg.ssm is not None else 5e-2
    full, _ = model.forward(params, batch)
    split = SEQ - 3
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :split]
    lg, cache = model.prefill(params, pre_batch, max_len=SEQ)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, split - 1]),
        rtol=tol, atol=tol)
    for i in range(split, SEQ):
        lg, cache = model.decode_step(params, batch["tokens"][:, i:i + 1],
                                      cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, i]),
            rtol=tol, atol=tol,
            err_msg=f"{arch} step {i}")


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "falcon-mamba-7b"])
def test_decode_parity_fp32(arch):
    """In fp32 the SSM decode recurrence matches the chunked prefill scan
    to ~1e-5 — proving the 0.1-band above is precision, not logic."""
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, BATCH, SEQ, jax.random.key(1))
    full, _ = model.forward(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :SEQ - 2]
    lg, cache = model.prefill(params, pre, max_len=SEQ)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, SEQ - 3]), atol=1e-4)
    for i in range(SEQ - 2, SEQ):
        lg, cache = model.decode_step(params, batch["tokens"][:, i:i + 1],
                                      cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, i]), atol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    """The exact configs must instantiate (metadata only) with plausible
    parameter counts for their published sizes."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "llama3-8b": (7e9, 9e9),
        "h2o-danube-1.8b": (1.5e9, 2.2e9),
        "llama3-405b": (390e9, 420e9),
        "falcon-mamba-7b": (6.5e9, 8e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
        "llama-3.2-vision-90b": (80e9, 100e9),
        "deepseek-moe-16b": (15e9, 18e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "whisper-base": (0.05e9, 0.12e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n/1e9:.2f}B params"
