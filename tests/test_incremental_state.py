"""Device-resident incremental state: parity is bit-for-bit, not close.

The tentpole contract of the incremental dispatch path: an allocator
state maintained by dirty-tile scatter updates (``repro.cluster.
device_state``) decides **bitwise identically** to the full re-pad path
it replaces, across both allocators, both sequential-core backends, and
federated layouts — at the allocator level (``allocate_batch_async`` vs
``allocate_batch``, including the fused maintain-and-decide step that
folds the dirty set into the decision dispatch), at the engine level
(``AllocatorConfig.incremental_state`` on vs off), and at the serving
level (``StreamEngine.serve()`` vs the offline ``run()``).

Donation note: ``allocate_batch_async`` with ``updates`` *consumes* the
input state (its tile buffers are donated to the fused dispatch), so
every chain below threads ``state = pending.state`` and never touches a
state it already passed in.
"""
import numpy as np
import pytest

from repro.api import AllocatorConfig, TimingConfig
from repro.cluster import device_state
from repro.cluster.device_state import DeviceResidualState
from repro.cluster.federation import FederatedLayout
from repro.core.allocator import RES_PAD, make_allocator
from repro.core.types import TaskBatch, TaskWindow
from repro.engine import EngineConfig, KubeAdaptor
from repro.serving import StreamEngine, serve_stream
from repro.workflows.spec import TaskSpec, WorkflowSpec

pytestmark = pytest.mark.tier1

N_NODES = 24


def _layout(k: int):
    return FederatedLayout.split(N_NODES, k) if k > 1 else None


def _cluster_arrays(rng):
    cap_cpu = rng.uniform(1000.0, 4000.0, N_NODES).astype(np.float32)
    cap_mem = rng.uniform(2000.0, 8000.0, N_NODES).astype(np.float32)
    res_cpu = (cap_cpu * rng.uniform(0.2, 1.0, N_NODES)).astype(np.float32)
    res_mem = (cap_mem * rng.uniform(0.2, 1.0, N_NODES)).astype(np.float32)
    return res_cpu, res_mem, cap_cpu, cap_mem


def _batch(rng, b: int) -> TaskBatch:
    cpu = rng.uniform(100.0, 900.0, b).astype(np.float32)
    mem = rng.uniform(200.0, 1800.0, b).astype(np.float32)
    return TaskBatch(
        cpu=cpu,
        mem=mem,
        min_cpu=(cpu * 0.25).astype(np.float32),
        min_mem=(mem * 0.25).astype(np.float32),
        window_end=rng.uniform(5.0, 50.0, b).astype(np.float32),
        self_slot=np.full((b,), -1, np.int32),
        pending=np.zeros((b,), bool),
    )


def _window(rng, t: int, now: float) -> TaskWindow:
    return TaskWindow(
        t_start=rng.uniform(0.0, now + 10.0, t).astype(np.float32),
        cpu=rng.uniform(100.0, 800.0, t).astype(np.float32),
        mem=rng.uniform(200.0, 1500.0, t).astype(np.float32),
        done=rng.uniform(size=t) < 0.3,
    )


def _assert_alloc_equal(a, b):
    for field in ("cpu", "mem", "node", "feasible", "attempted", "scenario"):
        got, want = getattr(a, field), getattr(b, field)
        assert np.array_equal(got, want), field


# ------------------------------------------------- DeviceResidualState

@pytest.mark.parametrize("k", [1, 2, 4])
def test_apply_updates_matches_recreate(k):
    """Scatter-updated tiles equal the tiles a fresh ``create`` would
    rebuild from the same host caches — element for element, block sums
    included."""
    rng = np.random.default_rng(7 + k)
    res_cpu, res_mem, cap_cpu, cap_mem = _cluster_arrays(rng)
    state = DeviceResidualState.create(
        res_cpu, res_mem, cap_cpu, cap_mem, _layout(k), RES_PAD)
    for trial in range(3):
        nodes = rng.choice(N_NODES, size=rng.integers(1, 6), replace=False)
        res_cpu[nodes] = (cap_cpu[nodes]
                          * rng.uniform(0.1, 1.0, nodes.size)).astype(
                              np.float32)
        res_mem[nodes] = (cap_mem[nodes]
                          * rng.uniform(0.1, 1.0, nodes.size)).astype(
                              np.float32)
        state = state.apply_updates(nodes, res_cpu[nodes], res_mem[nodes])
        fresh = DeviceResidualState.create(
            res_cpu, res_mem, cap_cpu, cap_mem, _layout(k), RES_PAD)
        for field in ("rc2", "rm2", "cc2", "cm2", "mask2",
                      "bsum_c", "bsum_m"):
            assert np.array_equal(np.asarray(getattr(state, field)),
                                  np.asarray(getattr(fresh, field))), \
                (field, trial)


def test_apply_updates_empty_is_noop():
    rng = np.random.default_rng(11)
    state = DeviceResidualState.create(
        *_cluster_arrays(rng), None, RES_PAD)
    assert state.apply_updates(np.zeros((0,), np.int64),
                               np.zeros((0,), np.float32),
                               np.zeros((0,), np.float32)) is state


def test_update_segment_buckets_have_a_floor():
    """Dirty-set buckets are floored so the fused decision jit (which
    inlines the scatter) does not recompile across the tiny per-burst
    dirty counts a streaming engine produces."""
    assert device_state._pow2(1) == device_state._MIN_BUCKET
    assert device_state._pow2(0) == device_state._MIN_BUCKET
    nodes = np.array([3, 4, 5])
    seg, n_idx, n_blk = device_state.pack_update_segment(
        nodes, np.ones(3, np.float32), np.ones(3, np.float32), None, 1)
    assert n_idx == device_state._MIN_BUCKET
    assert n_blk == device_state._MIN_BUCKET
    assert seg.shape == (3 * n_idx + n_blk,)
    # Int positions travel as raw float32 bits: bitcast-exact roundtrip.
    assert np.array_equal(seg[:3].view(np.int32), nodes.astype(np.int32))


# ------------------------------------------- allocator-level parity

_COMBOS = [(name, backend, k)
           for name in ("aras", "fcfs")
           for backend in ("scan", "pallas")
           for k in (1, 2, 4)]


@pytest.mark.parametrize("name,backend,k", _COMBOS)
def test_async_state_dispatch_matches_allocate_batch(name, backend, k):
    """The device-state dispatch (no pending updates) is bit-for-bit the
    re-pad dispatch."""
    rng = np.random.default_rng(hash((name, backend, k)) % 2**31)
    alloc = make_allocator(name, backend=backend, layout=_layout(k),
                           cluster_sharding="off")
    res_cpu, res_mem, cap_cpu, cap_mem = _cluster_arrays(rng)
    state = alloc.create_state(res_cpu, res_mem, cap_cpu, cap_mem)
    batch, window = _batch(rng, 5), _window(rng, 9, 4.0)
    want = alloc.allocate_batch(batch, res_cpu, res_mem, window, 4.0,
                                cap_cpu=cap_cpu, cap_mem=cap_mem)
    pending = alloc.allocate_batch_async(batch, window, 4.0, state=state)
    assert pending.state is state  # passthrough: nothing was folded
    _assert_alloc_equal(pending.wait(), want)


@pytest.mark.parametrize("name,backend,k", _COMBOS)
def test_fused_update_chain_matches_allocate_batch(name, backend, k):
    """The fused maintain-and-decide step — dirty deltas folded into the
    decision dispatch, state threaded through ``pending.state`` — stays
    bit-for-bit with re-padding the mutated host caches every burst."""
    rng = np.random.default_rng(hash((k, backend, name)) % 2**31)
    alloc = make_allocator(name, backend=backend, layout=_layout(k),
                           cluster_sharding="off")
    res_cpu, res_mem, cap_cpu, cap_mem = _cluster_arrays(rng)
    state = alloc.create_state(res_cpu, res_mem, cap_cpu, cap_mem)
    for trial in range(3):
        now = 2.0 * trial
        nodes = rng.choice(N_NODES, size=rng.integers(1, 6), replace=False)
        res_cpu[nodes] = (cap_cpu[nodes]
                          * rng.uniform(0.1, 1.0, nodes.size)).astype(
                              np.float32)
        res_mem[nodes] = (cap_mem[nodes]
                          * rng.uniform(0.1, 1.0, nodes.size)).astype(
                              np.float32)
        batch, window = _batch(rng, 4), _window(rng, 7, now)
        want = alloc.allocate_batch(batch, res_cpu, res_mem, window, now,
                                    cap_cpu=cap_cpu, cap_mem=cap_mem)
        pending = alloc.allocate_batch_async(
            batch, window, now, state=state,
            updates=(nodes, res_cpu[nodes].copy(), res_mem[nodes].copy()))
        state = pending.state  # the input state was donated — never reuse
        _assert_alloc_equal(pending.wait(), want)


def test_empty_burst_still_applies_updates():
    """A drain with no allocatable rows must not drop the dirty set."""
    rng = np.random.default_rng(23)
    alloc = make_allocator("aras")
    res_cpu, res_mem, cap_cpu, cap_mem = _cluster_arrays(rng)
    state = alloc.create_state(res_cpu, res_mem, cap_cpu, cap_mem)
    nodes = np.array([1, 5])
    res_cpu[nodes] = 42.0
    res_mem[nodes] = 84.0
    pending = alloc.allocate_batch_async(
        _batch(rng, 0), _window(rng, 3, 1.0), 1.0, state=state,
        updates=(nodes, res_cpu[nodes].copy(), res_mem[nodes].copy()))
    assert pending.wait().size == 0
    fresh = DeviceResidualState.create(
        res_cpu, res_mem, cap_cpu, cap_mem, None, RES_PAD)
    assert np.array_equal(np.asarray(pending.state.rc2),
                          np.asarray(fresh.rc2))
    assert np.array_equal(np.asarray(pending.state.bsum_m),
                          np.asarray(fresh.bsum_m))


# --------------------------------------------- engine-level parity

def _chain_wf(i: int, n_tasks: int = 2, duration: float = 6.0,
              cpu: float = 600.0) -> WorkflowSpec:
    tasks = {
        f"t{j}": TaskSpec(task_id=f"t{j}", image="img", cpu=cpu,
                          mem=2.0 * cpu, duration=duration + j,
                          min_cpu=cpu / 6.0, min_mem=cpu / 3.0)
        for j in range(n_tasks)
    }
    edges = [(f"t{j}", f"t{j + 1}") for j in range(n_tasks - 1)]
    return WorkflowSpec(workflow_id=f"w{i}", tasks=tasks, edges=edges)


_ARRIVALS = [(0.0, _chain_wf(0)), (0.5, _chain_wf(1, n_tasks=1)),
             (4.0, _chain_wf(2, duration=2.0)), (4.2, _chain_wf(3)),
             (11.0, _chain_wf(4, n_tasks=3, cpu=900.0))]


def _engine(name: str, k: int, window: float,
            incremental: bool) -> KubeAdaptor:
    return KubeAdaptor(EngineConfig(
        timing=TimingConfig(pod_startup_delay=1.0, cleanup_delay=1.0,
                            duration_multiplier=1.0, batch_window=window),
    ).evolve(allocator=name, num_clusters=k,
             incremental_state=incremental))


def _offline_metrics(name, k, window, incremental):
    eng = _engine(name, k, window, incremental)
    for t, wf in _ARRIVALS:
        eng.submit(wf, t)
    return eng.run()


def _assert_metrics_equal(a, b):
    assert a.alloc_trace == b.alloc_trace
    assert a.num_dispatches == b.num_dispatches
    assert a.num_allocations == b.num_allocations
    assert a.num_waits == b.num_waits
    assert a.makespan == b.makespan
    assert a.usage_series == b.usage_series
    assert a.workflow_durations == b.workflow_durations
    assert a.node_events == b.node_events
    assert a.displaced_tasks == b.displaced_tasks
    assert a.recovery_times == b.recovery_times
    assert a.failed_tasks == b.failed_tasks
    assert a.failed_workflows == b.failed_workflows


@pytest.mark.parametrize("name", ["aras", "fcfs"])
@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("window", [0.0, 3.0])
def test_engine_incremental_matches_repad(name, k, window):
    """``incremental_state`` flips the dispatch machinery, never the
    simulation: every metric of a full run is identical."""
    _assert_metrics_equal(_offline_metrics(name, k, window, True),
                          _offline_metrics(name, k, window, False))


def test_replay_mode_gates_device_state_off():
    """Per-task replay is *defined* as rebuilding the carry from host
    caches row by row — the device-state path must stand down."""
    eng = KubeAdaptor(EngineConfig(
        alloc=AllocatorConfig(batch_allocation=False)))
    assert not eng._use_device_state
    eng.submit(_chain_wf(0), 0.0)
    eng.run()
    assert eng._state is None


def test_incremental_state_config_gate():
    assert KubeAdaptor(EngineConfig())._use_device_state
    assert not KubeAdaptor(
        EngineConfig().evolve(incremental_state=False))._use_device_state


# --------------------------------------------- chaos-path parity

def _chaos_metrics(k: int, incremental: bool, schedule: str,
                   params: dict, oom_fraction: float = 0.0):
    eng = KubeAdaptor(EngineConfig(
        timing=TimingConfig(pod_startup_delay=1.0, cleanup_delay=1.0,
                            duration_multiplier=1.0, batch_window=3.0,
                            oom_fraction=oom_fraction),
    ).evolve(allocator="aras", num_clusters=k, incremental_state=incremental,
             fault_schedule=schedule, fault_params=params))
    for t, wf in _ARRIVALS:
        eng.submit(wf, t)
    return eng.run()


@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("schedule,params", [
    ("node_crash", {"at": 5.0, "nodes": 2}),
    ("node_flap", {"at": 3.0, "down_for": 6.0, "nodes": 2}),
])
def test_chaos_incremental_matches_repad(k, schedule, params):
    """Node down/up capacity deltas ride the same dirty-node journal as
    pod binds — the device-resident state stays bit-for-bit with the
    host re-pad path through cordons, drains, and restorations."""
    _assert_metrics_equal(_chaos_metrics(k, True, schedule, params),
                          _chaos_metrics(k, False, schedule, params))


def _vertical_metrics(k: int, incremental: bool):
    from repro.vertical import attach_usage
    eng = KubeAdaptor(EngineConfig(
        timing=TimingConfig(pod_startup_delay=1.0, cleanup_delay=1.0,
                            duration_multiplier=1.0, batch_window=3.0),
    ).evolve(allocator="aras", num_clusters=k, incremental_state=incremental,
             vertical=True, resize_interval=2.0))
    for t, wf in _ARRIVALS:
        eng.submit(attach_usage(wf, "ramp", {"start": 0.9, "end": 0.3}), t)
    return eng.run()


@pytest.mark.parametrize("k", [1, 2])
def test_vertical_resize_incremental_matches_repad(k):
    """RESIZE quota deltas ride the same dirty-node journal as binds and
    finishes — the device-resident state stays bit-for-bit with the host
    re-pad path through every in-place shrink and grow."""
    a = _vertical_metrics(k, True)
    b = _vertical_metrics(k, False)
    assert a.resize_events == b.resize_events and a.resize_events
    assert a.num_shrinks == b.num_shrinks
    assert a.reclaimed_cpu_seconds == b.reclaimed_cpu_seconds
    _assert_metrics_equal(a, b)


@pytest.mark.parametrize("k", [1, 2])
def test_oom_selfheal_incremental_matches_repad(k):
    """The OOM kill → reallocate-with-learned-floor loop under federation:
    identical healing with the dirty-tile dispatch on or off."""
    a = _chaos_metrics(k, True, "oom_storm", {"at": 4.0, "victims": 2},
                       oom_fraction=1.0)
    b = _chaos_metrics(k, False, "oom_storm", {"at": 4.0, "victims": 2},
                       oom_fraction=1.0)
    assert a.oom_events == b.oom_events and a.oom_events
    _assert_metrics_equal(a, b)


# --------------------------------------------- serving-level parity

@pytest.mark.parametrize("incremental", [True, False])
def test_stream_serve_matches_offline_run(incremental):
    """The pump feeds arrivals just in time; the windowed drain defines
    which arrivals a decision may fold — so serving a live stream equals
    submitting the schedule up front, bit for bit, with or without the
    device-state overlap."""
    offline = _offline_metrics("aras", 1, 3.0, incremental)
    eng = _engine("aras", 1, 3.0, incremental)
    stats = StreamEngine(eng, _ARRIVALS, prefetch_chunk=2).serve()
    _assert_metrics_equal(stats.metrics, offline)
    assert stats.decisions == offline.dispatched_rows
    assert stats.dispatches == offline.num_dispatches


def test_stream_serve_overlaps_ingestion_under_dispatch():
    """With the device-state path on, at least part of the arrival
    schedule is queued while a fused dispatch is in flight."""
    eng = _engine("aras", 1, 3.0, True)
    stats = StreamEngine(eng, _ARRIVALS, prefetch_chunk=2).serve()
    assert stats.overlapped_ingests > 0


def test_stream_rejects_unsorted_arrivals():
    eng = _engine("aras", 1, 0.0, True)
    with pytest.raises(ValueError, match="sorted"):
        StreamEngine(eng, [(1.0, _chain_wf(0)), (0.5, _chain_wf(1))])


def test_stream_stats_schema():
    """``to_dict`` is the schema CI's stream smoke step checks."""
    stats = serve_stream(_engine("fcfs", 1, 0.0, True), _ARRIVALS)
    d = stats.to_dict()
    assert set(d) == {"decisions", "dispatches", "wall_seconds",
                      "decisions_per_sec", "p50_latency_s",
                      "p99_latency_s", "overlapped_ingests",
                      "shed_workflows", "deferred_workflows"}
    assert d["shed_workflows"] == 0 and d["deferred_workflows"] == 0
    assert d["decisions"] > 0 and d["dispatches"] > 0
    assert d["decisions_per_sec"] > 0.0
    assert 0.0 < d["p50_latency_s"] <= d["p99_latency_s"]
    assert all(isinstance(v, (int, float)) for v in d.values())
