"""Batched burst allocation ≡ the per-task loop, bit for bit.

The correctness crux of the fused ``allocate_batch`` pipeline: driving
the engine one fused dispatch per arrival burst must reproduce the
sequential MAPE-K loop exactly — same makespan, same per-workflow
durations, same allocation trace (values *and* order), same OOM/
reallocation events, same utilization integrals.  Both modes execute the
same kernel against the same incremental float32 caches, so equality is
exact, not approximate.

(`num_waits` is deliberately not compared: the sequential loop counts a
wait per coalesced same-timestamp retry event, the batched drain counts
one per attempted row — the decisions themselves are identical.)

Also covers: the three placement policies, and batch edge cases (empty
batch, single task, all-infeasible burst).
"""
import numpy as np
import pytest

from repro.core.allocator import AdaptiveAllocator, FCFSAllocator
from repro.core.types import TaskBatch, TaskSpec, TaskWindow
from repro.core.placement import pick_node
from repro.engine import EngineConfig, TimingConfig, run_experiment
from repro.workflows import arrival

pytestmark = pytest.mark.tier1

FAST = EngineConfig(timing=TimingConfig(
    pod_startup_delay=1.0, cleanup_delay=1.0, duration_multiplier=1.0))

# Scaled-down versions of the paper's three §6.1.4 arrival patterns so
# each run stays test-sized while still producing multi-workflow bursts.
PATTERNS = {
    "constant": arrival.constant(y=2, bursts=3, interval=30.0),
    "linear": arrival.linear(k=1, d=1, bursts=3, interval=30.0),
    "pyramid": arrival.pyramid(start=1, peak=3, step=1, total=8,
                               interval=30.0),
}


def _run(kind, pattern, allocator, batched, task_kwargs=None, seed=0):
    cfg = FAST.evolve(batch_allocation=batched)
    return run_experiment(kind, pattern, allocator, seed=seed, config=cfg,
                          task_kwargs=task_kwargs)


def _assert_identical(batched, per_task):
    assert batched.makespan == per_task.makespan
    assert batched.workflow_durations == per_task.workflow_durations
    assert batched.alloc_trace == per_task.alloc_trace
    assert batched.oom_events == per_task.oom_events
    assert batched.realloc_events == per_task.realloc_events
    assert batched.num_allocations == per_task.num_allocations
    assert batched.avg_cpu_usage == per_task.avg_cpu_usage
    assert batched.avg_mem_usage == per_task.avg_mem_usage
    assert batched.usage_series == per_task.usage_series
    assert batched.sla_violations == per_task.sla_violations


@pytest.mark.parametrize("pattern_name", sorted(PATTERNS))
@pytest.mark.parametrize("kind", ["montage", "ligo"])
@pytest.mark.parametrize("allocator", ["aras", "fcfs"])
def test_engine_parity(pattern_name, kind, allocator):
    pattern = PATTERNS[pattern_name]
    _assert_identical(
        _run(kind, pattern, allocator, batched=True),
        _run(kind, pattern, allocator, batched=False),
    )


@pytest.mark.parametrize("allocator", ["aras", "fcfs"])
def test_engine_parity_other_kinds_burst(allocator):
    """Dense same-timestamp burst (max batch pressure) on the other DAGs."""
    for kind in ("epigenomics", "cybershake"):
        _assert_identical(
            _run(kind, [(0.0, 6)], allocator, batched=True, seed=3),
            _run(kind, [(0.0, 6)], allocator, batched=False, seed=3),
        )


def test_engine_parity_with_oom_selfheal():
    """Heal events flow through the batched drain identically (§6.2.2)."""
    kw = dict(mem=2600.0, min_mem=200.0, actual_min_mem=2000.0)
    b = _run("montage", [(0.0, 10)], "aras", batched=True, task_kwargs=kw)
    p = _run("montage", [(0.0, 10)], "aras", batched=False, task_kwargs=kw)
    assert len(b.oom_events) > 0  # the scenario actually exercises healing
    _assert_identical(b, p)


# ------------------------------------------------------------- placement

def _residuals():
    cpu = np.array([3000.0, 5000.0, 4000.0, 5000.0], np.float32)
    mem = np.array([8000.0, 500.0, 8000.0, 8000.0], np.float32)
    return cpu, mem


@pytest.mark.parametrize("policy,expected", [
    # node 1 has max CPU but not enough memory; among fitting {0, 2, 3}:
    ("worst_fit", 3),   # max residual CPU (ties → lowest index, so 3)
    ("best_fit", 0),    # min residual CPU
    ("first_fit", 0),   # lowest index
])
def test_placement_policies(policy, expected):
    cpu, mem = _residuals()
    node, fits = pick_node(cpu, mem, 2000.0, 1000.0, policy)
    assert bool(fits)
    assert int(node) == expected


def test_placement_worst_fit_prefers_max_cpu():
    cpu, mem = _residuals()
    # memory fits everywhere now -> worst-fit picks node 1 (5000, first max)
    mem = np.full_like(mem, 8000.0)
    node, fits = pick_node(cpu, mem, 2000.0, 1000.0, "worst_fit")
    assert (bool(fits), int(node)) == (True, 1)


def test_placement_nothing_fits():
    cpu, mem = _residuals()
    node, fits = pick_node(cpu, mem, 10000.0, 1000.0, "worst_fit")
    assert not bool(fits)


def test_placement_balanced_prefers_low_allocation_fractions():
    """kube NodeResourcesFit least-allocated: the node with the best mean
    free *fraction* after placement wins, not the most absolute CPU."""
    cpu = np.array([3000.0, 5000.0], np.float32)
    mem = np.array([8000.0, 2000.0], np.float32)
    cap_cpu = np.array([4000.0, 16000.0], np.float32)
    cap_mem = np.array([16000.0, 16000.0], np.float32)
    # worst_fit picks node 1 (max residual CPU) ...
    node, fits = pick_node(cpu, mem, 1000.0, 1000.0, "worst_fit")
    assert (bool(fits), int(node)) == (True, 1)
    # ... balanced picks node 0: free fractions (0.5, 0.4375) vs node 1's
    # (0.25, 0.0625).
    node, fits = pick_node(cpu, mem, 1000.0, 1000.0, "balanced",
                           cap_cpu=cap_cpu, cap_mem=cap_mem)
    assert (bool(fits), int(node)) == (True, 0)


def test_placement_balanced_requires_capacities():
    with pytest.raises(ValueError, match="balanced"):
        pick_node(*_residuals(), 1.0, 1.0, "balanced")


def test_placement_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown placement policy"):
        pick_node(*_residuals(), 1.0, 1.0, "wat")


@pytest.mark.parametrize("policy",
                         ["worst_fit", "best_fit", "first_fit", "balanced"])
def test_engine_runs_under_every_policy(policy):
    cfg = FAST.evolve(placement=policy)
    m = run_experiment("montage", [(0.0, 3)], "aras", seed=0, config=cfg)
    assert len(m.workflow_durations) == 3


@pytest.mark.parametrize("policy",
                         ["worst_fit", "best_fit", "first_fit", "balanced"])
@pytest.mark.parametrize("allocator", ["aras", "fcfs"])
def test_engine_parity_every_policy(policy, allocator):
    """Batched ≡ per-task replay under every placement policy."""
    def run(batched):
        cfg = FAST.evolve(batch_allocation=batched, placement=policy)
        return run_experiment("montage", [(0.0, 4)], allocator, seed=0,
                              config=cfg)

    _assert_identical(run(True), run(False))


# ------------------------------------------------------------ edge cases

def _cluster(n=2, cpu=8000.0, mem=16000.0):
    return (np.full((n,), cpu, np.float32), np.full((n,), mem, np.float32))


def _window_empty():
    z = np.zeros((0,), np.float32)
    return TaskWindow(t_start=z, cpu=z, mem=z, done=np.zeros((0,), bool))


def _task(i, cpu=2000.0, mem=4000.0, min_cpu=100.0, min_mem=1000.0):
    return TaskSpec(task_id=f"t{i}", image="i", cpu=cpu, mem=mem,
                    duration=10.0, min_cpu=min_cpu, min_mem=min_mem)


@pytest.mark.parametrize("alloc_cls", [AdaptiveAllocator, FCFSAllocator])
def test_empty_batch(alloc_cls):
    res_cpu, res_mem = _cluster()
    out = alloc_cls().allocate_batch(
        TaskBatch.from_tasks([], 0.0), res_cpu, res_mem, _window_empty(), 0.0
    )
    assert out.size == 0


@pytest.mark.parametrize("alloc_cls", [AdaptiveAllocator, FCFSAllocator])
def test_single_task_batch(alloc_cls):
    res_cpu, res_mem = _cluster()
    out = alloc_cls().allocate_batch(
        TaskBatch.from_tasks([_task(0)], 0.0), res_cpu, res_mem,
        _window_empty(), 0.0,
    )
    assert out.size == 1
    assert bool(out.feasible[0]) and bool(out.attempted[0])
    assert float(out.cpu[0]) == 2000.0 and float(out.mem[0]) == 4000.0
    assert int(out.node[0]) == 0


@pytest.mark.parametrize("alloc_cls", [AdaptiveAllocator, FCFSAllocator])
def test_all_infeasible_batch(alloc_cls):
    """Nothing fits: every row rejected, no node assigned, no debits
    corrupting later rows (row 2's view equals row 0's)."""
    res_cpu, res_mem = _cluster(n=2, cpu=50.0, mem=50.0)
    tasks = [_task(i, cpu=4000.0, mem=8000.0, min_cpu=3000.0,
                   min_mem=6000.0) for i in range(3)]
    out = alloc_cls().allocate_batch(
        TaskBatch.from_tasks(tasks, 0.0), res_cpu, res_mem,
        _window_empty(), 0.0,
    )
    assert not out.feasible.any()
    assert (out.node == -1).all()
    assert out.attempted.all()  # ready rows are always attempted


def test_batch_debits_are_sequential():
    """Each accepted row shrinks the residuals seen by the next one: a
    burst that collectively overflows one node spills onto the other, and
    once both are full the remaining rows are infeasible."""
    res_cpu, res_mem = _cluster(n=2, cpu=5000.0, mem=10000.0)
    tasks = [_task(i, cpu=4000.0, mem=8000.0, min_cpu=4000.0,
                   min_mem=7000.0) for i in range(3)]
    out = FCFSAllocator().allocate_batch(
        TaskBatch.from_tasks(tasks, 0.0), res_cpu, res_mem,
        _window_empty(), 0.0,
    )
    assert list(out.feasible) == [True, True, False]
    assert {int(out.node[0]), int(out.node[1])} == {0, 1}


def test_pending_head_of_line_blocking():
    """Pending rows keep the seed's FIFO head-of-line discipline: after
    the first pending failure, later pending rows are skipped (not
    attempted), while ready rows are still tried."""
    res_cpu, res_mem = _cluster(n=1, cpu=3000.0, mem=6000.0)
    big = _task(0, cpu=4000.0, mem=8000.0, min_cpu=4000.0, min_mem=7000.0)
    small = _task(1, cpu=1000.0, mem=2000.0)
    ready = _task(2, cpu=1000.0, mem=2000.0)
    batch = TaskBatch.from_tasks(
        [big, small, ready], 0.0, pending=[True, True, False]
    )
    out = FCFSAllocator().allocate_batch(
        batch, res_cpu, res_mem, _window_empty(), 0.0
    )
    assert list(out.attempted) == [True, False, True]
    assert list(out.feasible) == [False, False, True]
