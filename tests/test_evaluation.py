"""Exhaustive branch coverage of Algorithm 3 + Eq. 9 properties.

Property-based (hypothesis) companions live in
``tests/property/test_evaluation_props.py`` so this module collects on a
bare jax+pytest environment.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.evaluation import EvalInputs, evaluate, evaluate_batch

pytestmark = pytest.mark.tier1

ALPHA = 0.8


def ev(task_cpu, task_mem, req_cpu, req_mem, tot_cpu, tot_mem, remax_cpu, remax_mem):
    return evaluate(
        EvalInputs(
            jnp.float32(task_cpu), jnp.float32(task_mem),
            jnp.float32(req_cpu), jnp.float32(req_mem),
            jnp.float32(tot_cpu), jnp.float32(tot_mem),
            jnp.float32(remax_cpu), jnp.float32(remax_mem),
        ),
        ALPHA,
    )


def cuts(task_cpu, task_mem, req_cpu, req_mem, tot_cpu, tot_mem):
    return task_cpu * tot_cpu / req_cpu, task_mem * tot_mem / req_mem


# ---------------------------------------------------------------- scenario 1
# A1 ∧ A2 (sufficient cluster residuals) — paper Alg.3 lines 5-23.

def test_s1_b1_b2_full_request():
    r = ev(2000, 4000, 6000, 12000, 20000, 40000, 7000, 14000)
    assert (float(r.cpu), float(r.mem)) == (2000.0, 4000.0)
    assert int(r.scenario) == 0


def test_s1_not_b1_b2():  # request CPU exceeds best node -> α·Re_max_cpu
    r = ev(8000, 4000, 9000, 12000, 20000, 40000, 7000, 14000)
    assert float(r.cpu) == pytest.approx(7000 * ALPHA)
    assert float(r.mem) == 4000.0


def test_s1_b1_not_b2():
    r = ev(2000, 16000, 6000, 17000, 20000, 40000, 7000, 14000)
    assert float(r.cpu) == 2000.0
    assert float(r.mem) == pytest.approx(14000 * ALPHA)


def test_s1_not_b1_not_b2():
    r = ev(8000, 16000, 9000, 17000, 20000, 40000, 7000, 14000)
    assert float(r.cpu) == pytest.approx(7000 * ALPHA)
    assert float(r.mem) == pytest.approx(14000 * ALPHA)


# ---------------------------------------------------------------- scenario 2
# ¬A1 ∧ A2 (CPU-insufficient) — lines 25-43. CPU side uses C1/cpu_cut.

def test_s2_c1_b2_cpu_cut():
    # demand 40000 > residual 20000 -> cpu_cut = 2000*20000/40000 = 1000
    r = ev(2000, 4000, 40000, 12000, 20000, 40000, 7000, 14000)
    assert float(r.cpu) == pytest.approx(1000.0)
    assert float(r.mem) == 4000.0
    assert int(r.scenario) == 1


def test_s2_not_c1_b2():
    # cpu_cut = 6000*30000/40000 = 4500 > remax 4000 -> α·4000
    r = ev(6000, 4000, 40000, 12000, 30000, 40000, 4000, 14000)
    assert float(r.cpu) == pytest.approx(4000 * ALPHA)
    assert float(r.mem) == 4000.0


def test_s2_c1_not_b2():
    r = ev(2000, 16000, 40000, 17000, 20000, 40000, 7000, 14000)
    assert float(r.cpu) == pytest.approx(1000.0)
    assert float(r.mem) == pytest.approx(14000 * ALPHA)


def test_s2_not_c1_not_b2():
    r = ev(6000, 16000, 40000, 17000, 30000, 40000, 4000, 14000)
    assert float(r.cpu) == pytest.approx(4000 * ALPHA)
    assert float(r.mem) == pytest.approx(14000 * ALPHA)


# ---------------------------------------------------------------- scenario 3
# A1 ∧ ¬A2 (memory-insufficient) — lines 45-63. Mem side uses C2/mem_cut.

def test_s3_b1_c2_mem_cut():
    # mem demand 80000 > residual 40000 -> mem_cut = 4000*40000/80000 = 2000
    r = ev(2000, 4000, 6000, 80000, 20000, 40000, 7000, 14000)
    assert float(r.cpu) == 2000.0
    assert float(r.mem) == pytest.approx(2000.0)
    assert int(r.scenario) == 2


def test_s3_not_b1_c2():
    r = ev(8000, 4000, 9000, 80000, 20000, 40000, 7000, 14000)
    assert float(r.cpu) == pytest.approx(7000 * ALPHA)
    assert float(r.mem) == pytest.approx(2000.0)


def test_s3_b1_not_c2():
    # mem_cut = 12000*40000/80000 = 6000 > remax_mem 5000 -> α·5000
    r = ev(2000, 12000, 6000, 80000, 20000, 40000, 7000, 5000)
    assert float(r.cpu) == 2000.0
    assert float(r.mem) == pytest.approx(5000 * ALPHA)


def test_s3_not_b1_not_c2():
    r = ev(8000, 12000, 9000, 80000, 20000, 40000, 7000, 5000)
    assert float(r.cpu) == pytest.approx(7000 * ALPHA)
    assert float(r.mem) == pytest.approx(5000 * ALPHA)


# ---------------------------------------------------------------- scenario 4
# ¬A1 ∧ ¬A2 — lines 65-67: both cuts, no node-level clamping in the paper.

def test_s4_both_cuts():
    r = ev(2000, 4000, 40000, 80000, 20000, 40000, 7000, 14000)
    assert float(r.cpu) == pytest.approx(1000.0)
    assert float(r.mem) == pytest.approx(2000.0)
    assert int(r.scenario) == 3


# ------------------------------------------------------------------ batched

def test_batch_matches_scalar():
    tasks = np.array([[2000, 4000], [8000, 16000], [500, 800]], np.float32)
    reqs = np.array([[6000, 12000], [9000, 17000], [40000, 80000]], np.float32)
    batch = evaluate_batch(
        EvalInputs(
            jnp.asarray(tasks[:, 0]), jnp.asarray(tasks[:, 1]),
            jnp.asarray(reqs[:, 0]), jnp.asarray(reqs[:, 1]),
            jnp.float32(20000), jnp.float32(40000),
            jnp.float32(7000), jnp.float32(14000),
        ),
        0.8,
    )
    for i in range(3):
        r = ev(tasks[i, 0], tasks[i, 1], reqs[i, 0], reqs[i, 1],
               20000, 40000, 7000, 14000)
        assert float(batch.cpu[i]) == pytest.approx(float(r.cpu))
        assert float(batch.mem[i]) == pytest.approx(float(r.mem))
