"""Cross-shard parity: the federated allocator ≡ the single-cluster one.

Two regression gates for ``repro.cluster.federation``:

(a) the ``num_clusters=1`` federated path (K=1 layout, vector totals,
    per-shard argmax staging) is **bit-for-bit** the legacy allocator —
    array-level over random bursts for both allocators × all four
    placement policies × both sequential-core backends, and engine-level
    (``cluster_sharding="force"``) for batched *and* per-task replay
    modes;
(b) K clusters that partition the node table in order (so global node
    ids are preserved) reproduce the single-cluster accept/reject
    sequence, nodes and quotas exactly.  ARAS cases use integer-valued
    resources so the per-shard total fold re-associates exactly; FCFS
    never reads the totals, so it matches for arbitrary values.

Plus: scan ≡ pallas at K > 1, the multi-cluster ``ClusterSim`` mode
(layout metadata, sharded views, a deterministic bind/finish/delete fuzz
walk with invariants), layout/mesh plumbing, and the single-device
sharding fallback.
"""
import numpy as np
import pytest

from repro.cluster import federation
from repro.cluster.federation import FederatedLayout
from repro.cluster.simulator import ClusterSim
from repro.core.allocator import AdaptiveAllocator, FCFSAllocator
from repro.core.placement import PLACEMENT_POLICIES
from repro.core.types import Allocation, PodPhase, TaskBatch, TaskSpec, TaskWindow
from repro.engine import EngineConfig, TimingConfig, run_experiment

pytestmark = pytest.mark.tier1

FAST = EngineConfig(timing=TimingConfig(
    pod_startup_delay=1.0, cleanup_delay=1.0, duration_multiplier=1.0))

ALLOCATORS = (AdaptiveAllocator, FCFSAllocator)
FIELDS = ("cpu", "mem", "node", "feasible", "attempted", "scenario")


def _window_empty():
    z = np.zeros((0,), np.float32)
    return TaskWindow(t_start=z, cpu=z, mem=z, done=np.zeros((0,), bool))


def _window(rng, num_rec):
    return TaskWindow(
        t_start=rng.integers(0, 50, num_rec).astype(np.float32),
        cpu=rng.integers(0, 4000, num_rec).astype(np.float32),
        mem=rng.integers(0, 8000, num_rec).astype(np.float32),
        done=rng.random(num_rec) < 0.3,
    )


def _case(seed, m=11, num_rec=8, num_rows=6, *, integral):
    """Random burst against m nodes; ``integral`` draws integer-valued
    resources (exact under any float32 re-association)."""
    rng = np.random.default_rng(seed)
    draw = ((lambda lo, hi, n: rng.integers(lo, hi, n).astype(np.float32))
            if integral else
            (lambda lo, hi, n: rng.uniform(lo, hi, n).astype(np.float32)))
    res_cpu = draw(100, 8000, m)
    res_mem = draw(100, 16000, m)
    cap_cpu = np.full((m,), 8000.0, np.float32)
    cap_mem = np.full((m,), 16000.0, np.float32)
    tasks = [
        TaskSpec(task_id=f"t{i}", image="i",
                 cpu=float(draw(100, 6000, 1)[0]),
                 mem=float(draw(100, 12000, 1)[0]),
                 duration=10.0,
                 min_cpu=float(draw(1, 100, 1)[0]),
                 min_mem=float(draw(1, 200, 1)[0]))
        for i in range(num_rows)
    ]
    slots = rng.permutation(num_rec)[:num_rows].astype(np.int32)
    slots[rng.random(num_rows) < 0.25] = -1
    batch = TaskBatch.from_tasks(
        tasks, 5.0, self_slots=slots,
        pending=rng.random(num_rows) < 0.4,
    )
    return batch, res_cpu, res_mem, cap_cpu, cap_mem, _window(rng, num_rec)


def _decide(alloc_cls, layout, case, policy, backend="scan"):
    batch, res_cpu, res_mem, cap_cpu, cap_mem, window = case
    alloc = alloc_cls(placement=policy, backend=backend, layout=layout)
    return alloc.allocate_batch(batch, res_cpu, res_mem, window, 5.0,
                                cap_cpu=cap_cpu, cap_mem=cap_mem)


def _assert_batch_equal(a, b, ctx):
    for name in FIELDS:
        x, y = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert (x == y).all(), (ctx, name, x, y)


# ------------------------------------------------- (a) K=1 ≡ legacy, exact

@pytest.mark.parametrize("policy", PLACEMENT_POLICIES)
@pytest.mark.parametrize("alloc_cls", ALLOCATORS)
def test_single_cluster_layout_is_bitwise_legacy(alloc_cls, policy):
    """The K=1 federated layout is byte-identical to layout=None."""
    for seed in range(3):
        case = _case(seed, integral=False)  # arbitrary float32 values
        legacy = _decide(alloc_cls, None, case, policy)
        fed = _decide(alloc_cls, FederatedLayout.single(11), case, policy)
        _assert_batch_equal(legacy, fed, (alloc_cls.__name__, policy, seed))


@pytest.mark.parametrize("alloc_cls", ALLOCATORS)
def test_single_cluster_layout_bitwise_legacy_pallas(alloc_cls):
    """Same gate through the Pallas sequential core (interpret off-TPU)."""
    case = _case(0, integral=False)
    legacy = _decide(alloc_cls, None, case, "worst_fit", backend="pallas")
    fed = _decide(alloc_cls, FederatedLayout.single(11), case, "worst_fit",
                  backend="pallas")
    _assert_batch_equal(legacy, fed, alloc_cls.__name__)


def _engine_metrics_equal(a, b):
    assert a.makespan == b.makespan
    assert a.workflow_durations == b.workflow_durations
    assert a.alloc_trace == b.alloc_trace
    assert a.oom_events == b.oom_events
    assert a.realloc_events == b.realloc_events
    assert a.num_allocations == b.num_allocations
    assert a.usage_series == b.usage_series


@pytest.mark.parametrize("policy", PLACEMENT_POLICIES)
@pytest.mark.parametrize("allocator", ["aras", "fcfs"])
def test_engine_forced_federation_is_bitwise_legacy(allocator, policy):
    """cluster_sharding="force" routes num_clusters=1 through the K=1
    federated path; whole-simulation metrics must not move a bit."""
    def run(sharding):
        cfg = FAST.evolve(placement=policy, cluster_sharding=sharding)
        return run_experiment("montage", [(0.0, 3)], allocator, seed=0,
                              config=cfg)

    _engine_metrics_equal(run("auto"), run("force"))


@pytest.mark.parametrize("allocator", ["aras", "fcfs"])
def test_engine_forced_federation_replay_mode(allocator):
    """The per-task replay (batch_allocation=False) takes the same K=1
    federated path and still matches the legacy engine exactly."""
    def run(sharding):
        cfg = FAST.evolve(batch_allocation=False, cluster_sharding=sharding)
        return run_experiment("montage", [(0.0, 3)], allocator, seed=0,
                              config=cfg)

    _engine_metrics_equal(run("auto"), run("force"))


# ---------------------------------- (b) K shards ≡ single cluster, in order

@pytest.mark.parametrize("policy", PLACEMENT_POLICIES)
@pytest.mark.parametrize("alloc_cls", ALLOCATORS)
@pytest.mark.parametrize("counts", [(6, 5), (4, 4, 3), (5, 3, 2, 1)])
def test_federated_reproduces_single_cluster_sequence(alloc_cls, policy,
                                                      counts):
    """Order-preserving K-cluster partitions make the single-cluster
    decisions: same accept/reject sequence, same global nodes, same
    quotas.  Integer-valued resources keep the ARAS total fold exact."""
    for seed in range(3):
        case = _case(seed, m=sum(counts), integral=True)
        single = _decide(alloc_cls, None, case, policy)
        fed = _decide(alloc_cls, FederatedLayout(counts), case, policy)
        _assert_batch_equal(single, fed,
                            (alloc_cls.__name__, policy, counts, seed))


def test_federated_fcfs_any_values():
    """FCFS never reads the residual totals, so the federated sequence
    matches for arbitrary (non-integral) float32 resources too."""
    for seed in range(3):
        case = _case(seed, m=11, integral=False)
        single = _decide(FCFSAllocator, None, case, "worst_fit")
        fed = _decide(FCFSAllocator, FederatedLayout((4, 4, 3)), case,
                      "worst_fit")
        _assert_batch_equal(single, fed, seed)


@pytest.mark.parametrize("mode_cls", ALLOCATORS)
@pytest.mark.parametrize("policy", PLACEMENT_POLICIES)
def test_federated_scan_matches_pallas(mode_cls, policy):
    """Both sequential-core backends agree bit-for-bit at K > 1."""
    case = _case(1, m=9, integral=False)
    lay = FederatedLayout((4, 3, 2))
    ref = _decide(mode_cls, lay, case, policy, backend="scan")
    ker = _decide(mode_cls, lay, case, policy, backend="pallas")
    _assert_batch_equal(ref, ker, (mode_cls.__name__, policy))


@pytest.mark.parametrize("allocator", ["aras", "fcfs"])
def test_engine_multi_cluster_runs(allocator):
    """A 2-cluster engine drives workflows to completion under invariant
    checks; FCFS federations additionally reproduce the single-cluster
    metrics exactly (decisions are placement-only)."""
    cfg = FAST.evolve(num_clusters=2)
    fed = run_experiment("montage", [(0.0, 3)], allocator, seed=0,
                         config=cfg)
    assert len(fed.workflow_durations) == 3
    if allocator == "fcfs":
        single = run_experiment("montage", [(0.0, 3)], allocator, seed=0,
                                config=FAST)
        _engine_metrics_equal(single, fed)


# ------------------------------------------------------- layout & plumbing

def test_layout_split_and_perm():
    lay = FederatedLayout.split(10, 3)
    assert lay.node_counts == (4, 3, 3)
    assert lay.offsets == (0, 4, 7)
    assert lay.num_nodes == 10 and lay.num_clusters == 3
    perm = lay.node_perm
    assert perm.shape == (lay.num_blocks * 128,)
    # every global node appears exactly once, in cluster-major order
    real = perm[perm >= 0]
    assert sorted(real.tolist()) == list(range(10))
    # flat → global round-trips through global_nodes
    flat = np.flatnonzero(perm >= 0).astype(np.int32)
    assert (federation.global_nodes(flat, lay) == perm[flat]).all()
    assert federation.global_nodes(np.array([-1], np.int32), lay)[0] == -1


def test_layout_validation():
    with pytest.raises(ValueError, match="num_clusters"):
        FederatedLayout.split(3, 4)
    with pytest.raises(ValueError, match="at least one node"):
        FederatedLayout((2, 0))


def test_resolve_mesh_single_device_fallback():
    lay = FederatedLayout((3, 3))
    # On one device gcd(K, 1) == 1: no mesh, federated math unsharded.
    import jax
    mesh = federation.resolve_mesh(lay, "auto")
    if len(jax.devices()) == 1:
        assert mesh is None
    assert federation.resolve_mesh(lay, "off") is None
    assert federation.resolve_mesh(None, "auto") is None
    assert federation.resolve_mesh(FederatedLayout.single(4), "auto") is None
    with pytest.raises(ValueError, match="cluster_sharding"):
        federation.resolve_mesh(lay, "wat")


def test_cluster_sim_multi_cluster_metadata():
    sim = ClusterSim(7, 8000.0, 16000.0, num_clusters=3)
    assert sim.cluster_node_counts == (3, 2, 2)
    assert [s.stop - s.start for s in sim.cluster_slices] == [3, 2, 2]
    assert [sim.cluster_of(n) for n in range(7)] == [0, 0, 0, 1, 1, 2, 2]
    shards = sim.residual_view_sharded()
    caps = sim.capacity_view_sharded()
    assert len(shards) == 3 and len(caps) == 3
    # the sharded views alias the live flat arrays
    flat_cpu, _ = sim.residual_view()
    assert shards[0][0].base is flat_cpu
    assert federation.layout_of(sim) == FederatedLayout((3, 2, 2))
    with pytest.raises(ValueError, match="num_clusters"):
        ClusterSim(3, 8000.0, 16000.0, num_clusters=4)


def test_device_sharded_federation_matches_unsharded():
    """With 2 forced host devices, cluster_sharding="auto" builds the
    2-way ``clusters`` mesh and the device-sharded engine reproduces the
    unsharded federated metrics exactly (subprocess keeps this process
    at one device, like the dry-run tests)."""
    import os
    import subprocess
    import sys

    prog = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
from repro.engine import EngineConfig, TimingConfig, run_experiment
from repro.launch.mesh import make_cluster_mesh

assert len(jax.devices()) == 2
mesh = make_cluster_mesh(2)
assert mesh is not None and mesh.axis_names == ("clusters",), mesh
FAST = EngineConfig(timing=TimingConfig(
    pod_startup_delay=1.0, cleanup_delay=1.0, duration_multiplier=1.0))

def run(sharding):
    cfg = FAST.evolve(num_clusters=2, cluster_sharding=sharding)
    return run_experiment("montage", [(0.0, 2)], "fcfs", seed=0, config=cfg)

off, auto = run("off"), run("auto")
assert off.alloc_trace == auto.alloc_trace
assert off.makespan == auto.makespan
assert off.workflow_durations == auto.workflow_durations
print("SHARDED-PARITY-OK")
"""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=600, cwd=repo_root,
        env={**os.environ,
             "PYTHONPATH": os.path.join(repo_root, "src")},
    )
    assert out.returncode == 0, (out.stdout, out.stderr)
    assert "SHARDED-PARITY-OK" in out.stdout


def test_cluster_sim_fuzz_walk_invariants():
    """Deterministic bind/finish/delete random walk (single- and multi-
    cluster): invariants + O(1) utilization totals hold at every step.
    The hypothesis stateful twin lives in tests/property/."""
    for num_clusters in (1, 3):
        rng = np.random.default_rng(7)
        sim = ClusterSim(6, 8000.0, 16000.0, num_clusters=num_clusters)
        running, terminal, now = [], [], 0.0
        task = TaskSpec(task_id="t", image="i", cpu=1.0, mem=1.0,
                        duration=1.0, min_cpu=1.0, min_mem=1.0)
        for step in range(200):
            op = rng.random()
            if op < 0.5:
                node = int(rng.integers(0, sim.num_nodes))
                free_c = sim._alloc_cpu[node] - sim._used_cpu[node]
                free_m = sim._alloc_mem[node] - sim._used_mem[node]
                # Quotas floored to quarter-millicore/MiB granularity:
                # dyadic values at these magnitudes make the float64
                # books exact, like real (integral) K8s quantities.
                alloc = Allocation(
                    cpu=np.floor(free_c * rng.uniform(0, 1) * 4) / 4,
                    mem=np.floor(free_m * rng.uniform(0, 1) * 4) / 4,
                    node=node, feasible=True)
                running.append(sim.bind(task, alloc, now).uid)
            elif op < 0.8 and running:
                uid = running.pop(int(rng.integers(0, len(running))))
                phase = (PodPhase.SUCCEEDED if rng.random() < 0.7
                         else PodPhase.OOM_KILLED)
                sim.finish(uid, now, phase)
                terminal.append(uid)
            elif terminal:
                sim.delete(terminal.pop(int(rng.integers(0, len(terminal)))))
            now += 1.0
            sim.check_invariants()
            # O(1) utilization totals ≡ a from-scratch recompute
            u = sim.utilization()
            assert np.isclose(u.cpu, sim._used_cpu.sum() / sim._alloc_cpu.sum(),
                              rtol=1e-9, atol=1e-9)
            assert np.isclose(u.mem, sim._used_mem.sum() / sim._alloc_mem.sum(),
                              rtol=1e-9, atol=1e-9)
