"""ML-workload plane: ARAS-managed training jobs + straggler mitigation."""
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.engine.mljobs import MLJobResult, MLTaskSpec, run_ml_workflow
from repro.engine.straggler import SpeculativeMonitor, simulate_makespan

pytestmark = pytest.mark.slow


def _jobs(steps=12):
    cfg = get_smoke_config("qwen2-0.5b")
    return [
        MLTaskSpec("pretrain", cfg, steps=steps, batch=8, seq=16),
        MLTaskSpec("finetune-a", cfg, steps=steps, batch=8, seq=16,
                   depends_on=("pretrain",)),
        MLTaskSpec("finetune-b", cfg, steps=steps, batch=8, seq=16,
                   depends_on=("pretrain",)),
    ]


def test_ml_workflow_runs_dag(tmp_path):
    out = run_ml_workflow(_jobs(), cluster_mem=256.0,
                          ckpt_root=str(tmp_path))
    assert set(out) == {"pretrain", "finetune-a", "finetune-b"}
    for r in out.values():
        assert np.isfinite(r.final_loss)
        assert r.batch_used >= 1


def test_quota_scales_batch_under_contention(tmp_path):
    """With scarce cluster memory, ARAS scales the microbatch down
    (vertical autoscaling on the workload plane)."""
    out = run_ml_workflow(_jobs(steps=6), cluster_mem=40.0,
                          ckpt_root=str(tmp_path))
    assert out["pretrain"].batch_used < 8  # scaled below request
    assert all(np.isfinite(r.final_loss) for r in out.values())


def test_oom_selfheal_halves_batch_and_completes(tmp_path):
    out = run_ml_workflow(_jobs(steps=6), cluster_mem=256.0,
                          ckpt_root=str(tmp_path), inject_oom_once=True)
    assert out["pretrain"].restarts == 1
    assert out["pretrain"].batch_used <= 4  # halved from 8
    assert np.isfinite(out["pretrain"].final_loss)


# ------------------------------------------------------------ straggler

def test_speculation_reduces_heavy_tail_makespan():
    rng = np.random.default_rng(0)
    # 5% of tasks run 10-30x slower (environmental stragglers)
    d = rng.uniform(10, 20, size=400)
    stragglers = rng.random(400) < 0.05
    d = np.where(stragglers, d * rng.uniform(10, 30, 400), d)

    base = simulate_makespan(d, slots=16)
    spec = simulate_makespan(d, slots=16, monitor=SpeculativeMonitor())
    assert spec < base * 0.75, (base, spec)


def test_speculation_budget_respected():
    mon = SpeculativeMonitor(max_inflight_fraction=0.0)
    for _ in range(20):
        mon.observe(10.0)
    assert not mon.should_speculate("t", elapsed=1000.0, inflight=1,
                                    running=10)


def test_no_speculation_before_enough_samples():
    mon = SpeculativeMonitor(min_samples=8)
    for _ in range(3):
        mon.observe(10.0)
    assert not mon.should_speculate("t", elapsed=1000.0, inflight=0,
                                    running=10)
