"""Parallelism primitives: pipeline, compression, sharding policy.

These run on a small host-device mesh (subprocess sets the device count
where >1 devices are needed, keeping the main test process at 1 device).
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.compression import (
    dequantize_int8,
    make_pod_compressor,
    quantize_int8,
    simulate_roundtrip,
)

pytestmark = pytest.mark.slow


# --------------------------------------------------------- compression

def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(0), (256, 256)) * 3.0
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s)
    assert q.dtype == jnp.int8
    # max quantization error is half a step = scale/2
    assert float(jnp.abs(y - x).max()) <= float(s) * 0.5 + 1e-6


def test_stochastic_rounding_unbiased():
    x = jnp.full((10_000,), 0.3)
    q, s = quantize_int8(x * 127.0 / 0.9, jax.random.key(1))
    y = dequantize_int8(q, s)
    assert abs(float(jnp.mean(y)) - float(x[0]) * 127.0 / 0.9) < 0.05


def test_compressor_error_feedback_reduces_bias():
    grads = {"w": jax.random.normal(jax.random.key(2), (64, 64))}
    plain = simulate_roundtrip(grads)
    comp = make_pod_compressor(None, error_feedback=True)
    # accumulate the same gradient 20 times with/without feedback
    acc_plain = jnp.zeros_like(grads["w"])
    acc_ef = jnp.zeros_like(grads["w"])
    for _ in range(20):
        acc_plain += simulate_roundtrip(grads)["w"]
        acc_ef += comp(grads)["w"]
    target = grads["w"] * 20
    assert float(jnp.abs(acc_ef - target).mean()) <= \
        float(jnp.abs(acc_plain - target).mean()) + 1e-6


def test_train_step_with_compression_converges():
    """Quantized gradients must still train the smoke model."""
    from repro.configs import get_smoke_config
    from repro.data.synthetic import SyntheticDataset
    from repro.models.api import build_model
    from repro.optim import make_optimizer
    from repro.training import init_train_state, make_train_step

    cfg = get_smoke_config("llama3-8b")
    model = build_model(cfg)
    opt = make_optimizer("adamw", learning_rate=3e-3)
    ds = SyntheticDataset(cfg, batch=4, seq=16, seed=0)
    step = jax.jit(make_train_step(model, opt,
                                   compress_grads=simulate_roundtrip))
    state = init_train_state(model, opt, jax.random.key(0))
    losses = []
    for i in range(20):
        state, m = step(state, ds.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


# ------------------------------------------------------------ pipeline
# needs >1 device: run in a subprocess with forced host devices

_PIPELINE_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pp",))
S, M, mb, d = 4, 8, 2, 16
key = jax.random.key(0)
stage_params = jax.random.normal(key, (S, d, d)) / jnp.sqrt(d)
x = jax.random.normal(jax.random.key(1), (M, mb, d))

def body(w, h):
    return jnp.tanh(h @ w)

out = pipeline_apply(body, mesh, "pp", stage_params, x)

# oracle: sequential application of the 4 stages
ref = x
for s in range(S):
    ref = body(stage_params[s], ref.reshape(M * mb, d)).reshape(M, mb, d)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=1e-5, rtol=1e-5)
print("PIPELINE_OK")
"""


def test_pipeline_matches_sequential_oracle():
    r = subprocess.run(
        [sys.executable, "-c", _PIPELINE_PROG],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr


# ------------------------------------------------------- sharding policy

_POLICY_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.policy import ShardingPolicy

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
pol = ShardingPolicy(mesh)

# FSDP+TP on a weight: [D, F] -> (('pod','data'), 'model')
spec = pol.param_spec("layers/attn/wq", (64, 128))
assert spec == P(("pod", "data"), "model"), spec
# divisibility fallback: odd dim cannot shard
spec = pol.param_spec("layers/attn/wq", (63, 128))
assert spec == P(None, "model"), spec
assert any("63" in f for f in pol.fallbacks)
# experts shard over model (EP)
spec = pol.param_spec("layers/moe/experts_wg", (8, 64, 96))
assert spec == P("model", ("pod", "data"), None), spec
# adafactor factored stats mirror the parent param minus an axis
spec = pol.param_spec("stats/layers/attn/wq/vr", (64,))
assert spec == P(("pod", "data")), spec
spec = pol.param_spec("stats/layers/attn/wq/vc", (128,))
assert spec == P("model"), spec
# adam moments resolve to the parameter rule
spec = pol.param_spec("mu/layers/mlp/wd", (128, 64))
assert spec == P("model", ("pod", "data")), spec
print("POLICY_OK")
"""


def test_policy_specs():
    r = subprocess.run(
        [sys.executable, "-c", _POLICY_PROG],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert "POLICY_OK" in r.stdout, r.stdout + r.stderr
