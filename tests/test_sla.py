"""SLA/deadline semantics (paper Eqs. 2-4) + lifecycle-window bounding."""
import dataclasses

import numpy as np

from repro.core.allocator import AdaptiveAllocator
from repro.core.types import ClusterSnapshot, TaskSpec, TaskWindow
from repro.engine import EngineConfig, KubeAdaptor, TimingConfig
from repro.workflows.dags import montage
import pytest

pytestmark = pytest.mark.tier1

FAST = EngineConfig(timing=TimingConfig(
    pod_startup_delay=1.0, cleanup_delay=1.0, duration_multiplier=1.0))


def test_workflow_deadline_violation_recorded():
    eng = KubeAdaptor(FAST)
    wf = montage("m0", np.random.default_rng(0))
    wf = dataclasses.replace(wf, deadline=1.0)  # impossible deadline
    eng.submit(wf, 0.0)
    m = eng.run()
    assert len(m.sla_violations) == 1
    assert m.sla_violations[0][0] == "m0"
    assert m.sla_violation_rate == 1.0


def test_generous_deadline_not_violated():
    eng = KubeAdaptor(FAST)
    wf = montage("m0", np.random.default_rng(0))
    wf = dataclasses.replace(wf, deadline=1e6)
    eng.submit(wf, 0.0)
    m = eng.run()
    assert m.sla_violations == []
    assert m.sla_violation_rate == 0.0


def test_task_deadline_bounds_lifecycle_window():
    """Alg. 1: the in-window accumulation uses [now, min(now+duration,
    deadline)) — a tight task deadline must shrink the competitor set."""
    snap = ClusterSnapshot(
        allocatable_cpu=np.array([8000.0], np.float32),
        allocatable_mem=np.array([16000.0], np.float32),
        pod_node=np.zeros((0,), np.int32),
        pod_cpu=np.zeros((0,), np.float32),
        pod_mem=np.zeros((0,), np.float32),
        pod_active=np.zeros((0,), bool),
    )
    # competitors starting at t=5 and t=15
    window = TaskWindow(
        t_start=np.array([5.0, 15.0], np.float32),
        cpu=np.array([4000.0, 4000.0], np.float32),
        mem=np.array([8000.0, 8000.0], np.float32),
        done=np.array([False, False]),
    )
    alloc = AdaptiveAllocator()
    base = dict(task_id="t", image="i", cpu=2000.0, mem=4000.0,
                duration=20.0, min_cpu=100.0, min_mem=100.0)

    # without deadline: window [0, 20) sees both competitors
    a_full = alloc.allocate(TaskSpec(**base), snap, window, now=0.0)
    # deadline at t=10: window [0, 10) sees only the first
    a_tight = alloc.allocate(TaskSpec(**base, deadline=10.0), snap,
                             window, now=0.0)
    # less in-window demand => the tight-deadline allocation is >= the
    # full-window one (scaling divides by smaller accumulated request)
    assert a_tight.mem >= a_full.mem - 1e-6
    assert a_tight.cpu >= a_full.cpu - 1e-6
