"""The event subsystem: typed queue, windowed drain, stochastic arrivals.

Covers ``repro.engine.events`` (EventKind ordering, FIFO within a kind,
the ``pop_mergeable`` fold rule), the engine-level windowed drain
(``TimingConfig.batch_window``: jittered arrivals fold into fused
dispatches, decision stamped at the last folded arrival, window
boundaries inclusive), the new stochastic ``ARRIVALS`` entries
(``poisson`` / ``jittered`` / ``trace``), the Scenario seed wiring for
``stochastic``-flagged patterns, and the headline acceptance claim: a
poisson workload under a positive window makes the same decisions in
*fewer* dispatches than the lockstep ``batch_window=0`` drain.
"""
import dataclasses

import pytest

from repro.api import Scenario, TimingConfig, grid, run_scenario
from repro.engine import EngineConfig, KubeAdaptor
from repro.engine.events import ALLOCATABLE, Event, EventKind, EventQueue
from repro.workflows import arrival
from repro.workflows.spec import TaskSpec, WorkflowSpec

pytestmark = pytest.mark.tier1

FAST = EngineConfig(timing=TimingConfig(
    pod_startup_delay=1.0, cleanup_delay=1.0, duration_multiplier=1.0))


# ------------------------------------------------------------ EventQueue

def test_event_kind_heap_order():
    """At equal timestamps: completions/deletions before retries before
    injects/readies, and HEAL after same-time READY."""
    q = EventQueue()
    kinds = [EventKind.HEAL, EventKind.READY, EventKind.INJECT,
             EventKind.RETRY, EventKind.DELETE, EventKind.OOM,
             EventKind.COMPLETE]
    for kind in kinds:
        q.push(5.0, kind)
    assert [q.pop().kind for _ in range(len(kinds))] == sorted(kinds)
    assert not q


def test_time_beats_kind_and_seq_is_fifo():
    q = EventQueue()
    q.push(2.0, EventKind.COMPLETE, ("late",))
    q.push(1.0, EventKind.READY, ("first",))
    q.push(1.0, EventKind.READY, ("second",))
    assert q.pop().payload == ("first",)   # FIFO within (t, kind)
    assert q.pop().payload == ("second",)
    assert q.pop().payload == ("late",)    # later time last, despite kind


def test_peek_and_len():
    q = EventQueue()
    assert q.peek() is None and len(q) == 0 and not q
    ev = q.push(3.0, EventKind.RETRY)
    assert isinstance(ev, Event)
    assert q.peek() == ev and len(q) == 1 and bool(q)


@pytest.mark.parametrize("kind", sorted(ALLOCATABLE))
def test_pop_mergeable_allocatable_within_deadline(kind):
    q = EventQueue()
    q.push(4.0, kind)
    assert q.pop_mergeable(0.0, 3.9) is None     # beyond the deadline
    assert q.pop_mergeable(0.0, 4.0).kind == kind  # boundary is inclusive
    assert q.pop_mergeable(0.0, 4.0) is None     # empty queue


def test_pop_mergeable_capacity_events_block():
    for kind in (EventKind.COMPLETE, EventKind.OOM, EventKind.DELETE):
        q = EventQueue()
        q.push(1.0, kind)
        q.push(1.0, EventKind.READY)
        assert q.pop_mergeable(0.0, 10.0) is None, kind
        assert len(q) == 2  # nothing consumed


def test_pop_mergeable_inject_requires_strictly_later_time():
    q = EventQueue()
    q.push(1.0, EventKind.INJECT)
    # A same-timestamp INJECT never folds (the legacy drain split there),
    # so the clause is unreachable at batch_window=0.
    assert q.pop_mergeable(1.0, 1.0) is None
    assert q.pop_mergeable(0.5, 1.0).kind is EventKind.INJECT


@pytest.mark.parametrize("kind", [EventKind.COMPLETE, EventKind.DELETE])
def test_pop_mergeable_fold_capacity_free_folds_later_events(kind):
    """Clause (c): with ``fold_capacity_free`` a strictly-later COMPLETE
    or DELETE within the deadline folds through — the engine passes the
    flag only while the drained burst holds no undecided request."""
    q = EventQueue()
    q.push(1.0, kind)
    assert q.pop_mergeable(0.0, 10.0) is None          # default still blocks
    assert q.pop_mergeable(0.0, 0.9, fold_capacity_free=True) is None
    got = q.pop_mergeable(0.0, 1.0, fold_capacity_free=True)  # inclusive
    assert got is not None and got.kind is kind
    assert not q


def test_pop_mergeable_fold_capacity_free_same_time_blocks():
    # Strictly later only: unreachable at batch_window=0, where deadline
    # == head_t, preserving the seed's lockstep drain bit for bit.
    q = EventQueue()
    q.push(1.0, EventKind.COMPLETE)
    assert q.pop_mergeable(1.0, 1.0, fold_capacity_free=True) is None
    assert len(q) == 1


def test_pop_mergeable_oom_never_folds():
    # OOM mutates a pod's outcome (self-healing) and must anchor its own
    # drain, flag or no flag.
    q = EventQueue()
    q.push(1.0, EventKind.OOM)
    assert q.pop_mergeable(0.0, 10.0, fold_capacity_free=True) is None
    assert len(q) == 1


# ------------------------------------------------- windowed drain, engine

def _single_task_wf(i: int, duration: float = 60.0) -> WorkflowSpec:
    # Twin of tests/property/test_window_props.py::_single_task_wf —
    # keep the task shape in sync (duration far beyond every test's
    # arrival span, so completions never interrupt the drained windows).
    task = TaskSpec(task_id="t0", image="i", cpu=600.0, mem=1200.0,
                    duration=duration, min_cpu=100.0, min_mem=200.0)
    return WorkflowSpec(workflow_id=f"w{i}", tasks={"t0": task}, edges=[])


def _run_jittered(window: float, times, submit_order=None):
    eng = KubeAdaptor(FAST.evolve(batch_window=window))
    order = submit_order if submit_order is not None else range(len(times))
    for i in order:
        eng.submit(_single_task_wf(i), times[i])
    metrics = eng.run()
    return metrics


def test_window_folds_jittered_arrivals_into_one_dispatch():
    times = [0.0, 2.0, 4.0, 6.0]
    m = _run_jittered(10.0, times)
    assert m.num_allocations == 4
    assert m.num_dispatches == 1
    assert m.mean_burst_width == 4.0
    # The fused decision is made at the *last* folded arrival (t=6), so
    # every pod starts there — never before its own request exists.
    assert [t for t, *_ in m.alloc_trace] == [6.0] * 4


def test_window_zero_dispatches_per_distinct_timestamp():
    """The legacy lockstep contract: batch_window=0 decides each distinct
    arrival timestamp on its own."""
    times = [0.0, 2.0, 4.0, 6.0]
    m = _run_jittered(0.0, times)
    assert m.num_allocations == 4
    assert m.num_dispatches == len(set(times))
    assert m.mean_burst_width == 1.0
    assert [t for t, *_ in m.alloc_trace] == times


def test_window_boundary_is_inclusive():
    assert _run_jittered(10.0, [0.0, 10.0]).num_dispatches == 1
    assert _run_jittered(10.0, [0.0, 10.5]).num_dispatches == 2


def test_window_same_timestamp_burst_is_window_invariant():
    """A lockstep burst already folds maximally at window=0, so any
    window must reproduce it exactly."""
    times = [5.0] * 4
    m0 = _run_jittered(0.0, times)
    mw = _run_jittered(30.0, times)
    assert m0.num_dispatches == mw.num_dispatches == 1
    assert m0.alloc_trace == mw.alloc_trace
    assert m0.makespan == mw.makespan
    assert m0.usage_series == mw.usage_series


def test_window_larger_than_burst_gap_folds_across_bursts():
    """Decide-at-t+ε taken literally: a window spanning the gap to the
    next arrival folds that arrival into the current decision, so the
    window-0 invariance contract is per-burst, not per-pattern."""
    times = [0.0, 0.0, 20.0]
    m = _run_jittered(20.0, times)
    assert m.num_dispatches == 1  # t=20 arrival joined the t=0 burst
    assert [t for t, *_ in m.alloc_trace] == [20.0] * 3
    m0 = _run_jittered(19.5, times)
    assert m0.num_dispatches == 2  # window short of the gap: two bursts
    assert [t for t, *_ in m0.alloc_trace] == [0.0, 0.0, 20.0]


def test_window_folds_idle_completions_through_the_drain():
    """Short-task streams no longer fragment on their own completions:
    a RETRY-anchored drain with no undecided rows folds strictly-later
    COMPLETE/DELETE events through (clause (c) of ``pop_mergeable``),
    settling the run in fewer event-loop steps while the decisions,
    dispatch count, and allocation trace stay identical to lockstep."""
    def drive(window):
        eng = KubeAdaptor(FAST.evolve(batch_window=window))
        eng.submit(_single_task_wf(0, duration=2.0), 0.0)
        eng.submit(_single_task_wf(1, duration=2.5), 0.0)
        # Arrives between the first completion's RETRY anchor (t=3) and
        # that anchor's deadline (t=5), but beyond the t=0 burst's own
        # window — only the folded-through drain catches it in one step.
        eng.submit(_single_task_wf(2, duration=2.0), 4.2)
        steps = 0
        while eng.queue:
            eng.step()
            steps += 1
        return steps, eng.finalize()

    steps_w, m_w = drive(2.0)
    steps_0, m_0 = drive(0.0)
    assert m_w.num_allocations == m_0.num_allocations == 3
    assert m_w.num_dispatches == m_0.num_dispatches == 2
    assert m_w.alloc_trace == m_0.alloc_trace
    assert steps_w < steps_0


def test_window_invariant_to_submission_order():
    """Arrivals inside one window fold in timestamp order regardless of
    the order the workflows were submitted in."""
    times = [0.0, 2.0, 4.0, 6.0]
    a = _run_jittered(10.0, times)
    b = _run_jittered(10.0, times, submit_order=[2, 0, 3, 1])
    assert a.alloc_trace == b.alloc_trace
    assert a.makespan == b.makespan
    assert a.workflow_durations == b.workflow_durations
    assert a.num_dispatches == b.num_dispatches


def test_replay_mode_counts_per_row_dispatches():
    eng = KubeAdaptor(FAST.evolve(batch_window=10.0,
                                  batch_allocation=False))
    for i, t in enumerate([0.0, 2.0, 4.0]):
        eng.submit(_single_task_wf(i), t)
    m = eng.run()
    assert m.num_allocations == 3
    assert m.num_dispatches == 3  # one device dispatch per replayed row
    assert m.mean_burst_width == 1.0


def test_batch_window_validates():
    with pytest.raises(ValueError, match="batch_window"):
        EngineConfig(timing=TimingConfig(batch_window=-1.0)).validate()
    assert FAST.evolve(batch_window=2.5).timing.batch_window == 2.5


# ------------------------------------------------- stochastic arrivals

def test_poisson_pattern_shape_and_determinism():
    p = arrival.poisson(lam=5.0, bursts=6, interval=300.0, seed=7)
    assert p == arrival.poisson(lam=5.0, bursts=6, interval=300.0, seed=7)
    assert p != arrival.poisson(lam=5.0, bursts=6, interval=300.0, seed=8)
    times = [t for t, _ in p]
    assert times == sorted(times)
    assert all(0.0 <= t < 1800.0 for t in times)
    assert all(n == 1 for _, n in p)  # per-workflow arrivals
    with pytest.raises(ValueError, match="lam"):
        arrival.poisson(lam=0.0)
    with pytest.raises(ValueError, match="bursts"):
        arrival.poisson(bursts=0)


def test_jittered_pattern_disperses_base_bursts():
    base = arrival.linear(k=1, d=1, bursts=3, interval=30.0)
    p = arrival.jittered(base="linear", jitter=10.0, seed=0,
                         base_params={"k": 1, "d": 1, "bursts": 3,
                                      "interval": 30.0})
    assert arrival.total_workflows(p) == arrival.total_workflows(base)
    assert all(n == 1 for _, n in p)
    times = [t for t, _ in p]
    assert times == sorted(times)
    # every jittered arrival stays within [t_burst, t_burst + jitter)
    starts = [t for t, n in base for _ in range(n)]
    assert all(any(s <= t < s + 10.0 for s in set(starts)) for t in times)
    with pytest.raises(ValueError, match="deterministic"):
        arrival.jittered(base="poisson")
    with pytest.raises(ValueError, match="jitter"):
        arrival.jittered(jitter=-1.0)


def test_trace_pattern_replays_and_coalesces():
    p = arrival.trace(times=[30.0, 0.0, 30.0, (60.0, 2), 0.0])
    assert p == [(0.0, 2), (30.0, 2), (60.0, 2)]
    assert arrival.total_workflows(p) == 6
    assert arrival.trace() == []
    with pytest.raises(ValueError, match="finite"):
        arrival.trace(times=[-1.0])
    with pytest.raises(ValueError, match="counts"):
        arrival.trace(times=[(1.0, 0)])


def test_scenario_wires_seed_into_stochastic_arrivals():
    sc3 = Scenario(arrival="poisson", arrival_params={"lam": 4.0},
                   seed=3)
    sc4 = dataclasses.replace(sc3, seed=4)
    assert sc3.pattern() == arrival.poisson(lam=4.0, seed=3)
    assert sc4.pattern() == arrival.poisson(lam=4.0, seed=4)
    assert sc3.pattern() != sc4.pattern()
    # an explicit arrival seed pins the arrivals across scenario seeds
    pinned = dataclasses.replace(
        sc3, arrival_params={"lam": 4.0, "seed": 11})
    assert pinned.pattern() == arrival.poisson(lam=4.0, seed=11)
    # deterministic patterns never see a seed kwarg
    det = Scenario(arrival="constant", seed=3)
    assert det.pattern() == arrival.constant()
    sc3.validate()  # signature-binds with the wired seed


def test_grid_seed_axis_replicates_scenarios():
    base = Scenario(name="g", engine=FAST, arrival="poisson")
    sweep = grid(base, allocators=("aras",), arrivals=("poisson",),
                 seeds=(0, 1, 2))
    assert len(sweep) == 3
    assert [s.seed for s in sweep] == [0, 1, 2]
    assert {s.name for s in sweep} == {"g-aras-poisson-s0",
                                       "g-aras-poisson-s1",
                                       "g-aras-poisson-s2"}
    patterns = [s.pattern() for s in sweep]
    assert patterns[0] != patterns[1]  # seeds really re-draw arrivals
    # no seeds axis: names and seeds stay as before
    legacy = grid(base, allocators=("aras",), arrivals=("constant",))
    assert [s.name for s in legacy] == ["g-aras-constant"]
    assert legacy[0].seed == base.seed


# ---------------------------------------------- acceptance: fewer fuses

def test_poisson_window_reduces_dispatches_at_equal_decisions():
    """The PR's headline claim: under a stochastic arrival stream, a
    positive batch_window folds jittered arrivals into fewer fused
    dispatches while making the same number of allocation decisions.
    (64 nodes keep the pending queue short; under heavy contention the
    repeated pending-retry rows drown the arrival-fold signal in
    mean_burst_width, though the dispatch reduction still holds.)"""
    wide = FAST.evolve(num_nodes=64, node_cpu=8000.0, node_mem=16000.0)
    base = Scenario(
        name="poisson-win", workflows=("montage",), arrival="poisson",
        arrival_params={"lam": 12.0, "bursts": 1, "interval": 10.0},
        engine=wide, seed=1,
    )
    lockstep = run_scenario(base)
    windowed = run_scenario(dataclasses.replace(
        base, engine=wide.evolve(batch_window=10.0)))
    assert windowed.num_workflows == lockstep.num_workflows
    assert windowed.num_allocations == lockstep.num_allocations
    assert windowed.num_dispatches < lockstep.num_dispatches
    assert windowed.mean_burst_width > lockstep.mean_burst_width
