"""Stateful fuzz of ``ClusterSim`` — invariants under random lifecycles.

A hypothesis ``RuleBasedStateMachine`` drives random ``bind`` /
``finish`` / ``delete`` sequences against single- and multi-cluster
simulators, calling ``check_invariants()`` after every rule.  On top of
the simulator's own checks (non-negative books, overcommit bounds,
pod-array cross-checks, float32 mirror drift) the machine asserts that
the O(1) incrementally-carried utilization totals stay equal to a
from-scratch recompute of the node books — the accounting the engine
samples on every bind/finish.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import settings, strategies as st  # noqa: E402
from hypothesis.stateful import (  # noqa: E402
    RuleBasedStateMachine,
    initialize,
    invariant,
    precondition,
    rule,
)

from repro.cluster.simulator import ClusterSim  # noqa: E402
from repro.core.types import Allocation, PodPhase, TaskSpec  # noqa: E402

pytestmark = pytest.mark.tier1

_TASK = TaskSpec(task_id="fuzz", image="i", cpu=1.0, mem=1.0,
                 duration=1.0, min_cpu=1.0, min_mem=1.0)


class ClusterLifecycleMachine(RuleBasedStateMachine):
    @initialize(num_nodes=st.integers(1, 9), num_clusters=st.integers(1, 4),
                node_cpu=st.sampled_from([800.0, 6800.0]),
                node_mem=st.sampled_from([1600.0, 13600.0]))
    def setup(self, num_nodes, num_clusters, node_cpu, node_mem):
        self.sim = ClusterSim(num_nodes, node_cpu, node_mem,
                              num_clusters=min(num_clusters, num_nodes))
        self.now = 0.0
        self.running = []
        self.terminal = []

    @rule(node_pick=st.integers(0, 10**6),
          cpu_frac=st.floats(0.0, 1.0, allow_nan=False),
          mem_frac=st.floats(0.0, 1.0, allow_nan=False))
    def bind(self, node_pick, cpu_frac, mem_frac):
        """Bind a pod sized as a fraction of the node's free capacity —
        always admissible, so every overcommit raise would be a bug.
        Quotas are floored to quarter-unit granularity: dyadic values at
        these magnitudes keep the float64 books exact, like the integral
        millicore/MiB quantities real pods request."""
        node = node_pick % self.sim.num_nodes
        free_cpu = self.sim._alloc_cpu[node] - self.sim._used_cpu[node]
        free_mem = self.sim._alloc_mem[node] - self.sim._used_mem[node]
        alloc = Allocation(
            cpu=float(np.floor(max(free_cpu, 0.0) * cpu_frac * 4) / 4),
            mem=float(np.floor(max(free_mem, 0.0) * mem_frac * 4) / 4),
            node=node, feasible=True)
        pod = self.sim.bind(_TASK, alloc, self.now)
        self.running.append(pod.uid)
        self.now += 1.0

    @precondition(lambda self: self.running)
    @rule(pick=st.integers(0, 10**6),
          phase=st.sampled_from([PodPhase.SUCCEEDED, PodPhase.FAILED,
                                 PodPhase.OOM_KILLED]))
    def finish(self, pick, phase):
        uid = self.running.pop(pick % len(self.running))
        self.sim.finish(uid, self.now, phase)
        self.terminal.append(uid)
        self.now += 1.0

    @precondition(lambda self: self.terminal)
    @rule(pick=st.integers(0, 10**6))
    def delete(self, pick):
        self.sim.delete(self.terminal.pop(pick % len(self.terminal)))

    @invariant()
    def invariants_hold(self):
        if not hasattr(self, "sim"):
            return  # before @initialize
        self.sim.check_invariants()
        # O(1)-carried utilization totals ≡ from-scratch recompute
        u = self.sim.utilization()
        assert np.isclose(
            u.cpu, self.sim._used_cpu.sum() / self.sim._alloc_cpu.sum(),
            rtol=1e-9, atol=1e-9)
        assert np.isclose(
            u.mem, self.sim._used_mem.sum() / self.sim._alloc_mem.sum(),
            rtol=1e-9, atol=1e-9)
        # sharded views stay consistent with the flat live arrays
        res_cpu, res_mem = self.sim.residual_view()
        for sl, (c, m) in zip(self.sim.cluster_slices,
                              self.sim.residual_view_sharded()):
            assert np.shares_memory(c, res_cpu) and (c == res_cpu[sl]).all()
            assert np.shares_memory(m, res_mem) and (m == res_mem[sl]).all()


ClusterLifecycleMachine.TestCase.settings = settings(
    max_examples=20, stateful_step_count=40, deadline=None)

TestClusterLifecycle = ClusterLifecycleMachine.TestCase
