"""Properties of the paper's three arrival patterns (§6.1.4, Fig. 5a-c).

The generators (``repro.workflows.arrival``) feed every experiment, yet
were untested: under hypothesis-drawn parameters, each pattern must emit
non-decreasing timestamps, strictly positive burst sizes, and a
``total_workflows`` equal to the sum of per-burst counts — with the
pattern-specific totals (``y·bursts`` for constant, ``Σ(d + k·i)`` for
linear, exactly the requested ``total`` for pyramid) matching in closed
form.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

from repro.workflows import arrival  # noqa: E402

pytestmark = pytest.mark.tier1

_interval = st.floats(min_value=0.5, max_value=3600.0,
                      allow_nan=False, allow_infinity=False)


def _check_common(pattern):
    times = [t for t, _ in pattern]
    counts = [n for _, n in pattern]
    assert times == sorted(times), times
    assert all(n > 0 for n in counts), counts
    assert arrival.total_workflows(pattern) == sum(counts)
    return times, counts


@given(y=st.integers(1, 20), bursts=st.integers(1, 12), interval=_interval)
def test_constant_pattern(y, bursts, interval):
    pattern = arrival.constant(y=y, bursts=bursts, interval=interval)
    times, counts = _check_common(pattern)
    assert len(pattern) == bursts
    assert counts == [y] * bursts
    assert arrival.total_workflows(pattern) == y * bursts
    assert times == [i * interval for i in range(bursts)]


@given(k=st.integers(0, 6), d=st.integers(1, 6), bursts=st.integers(1, 10),
       interval=_interval)
def test_linear_pattern(k, d, bursts, interval):
    pattern = arrival.linear(k=k, d=d, bursts=bursts, interval=interval)
    times, counts = _check_common(pattern)
    assert len(pattern) == bursts
    assert counts == [d + k * i for i in range(bursts)]
    assert arrival.total_workflows(pattern) == \
        sum(d + k * i for i in range(bursts))


@given(start=st.integers(1, 5), peak_delta=st.integers(0, 8),
       step=st.integers(1, 4), total=st.integers(1, 80), interval=_interval)
def test_pyramid_pattern(start, peak_delta, step, total, interval):
    pattern = arrival.pyramid(start=start, peak=start + peak_delta,
                              step=step, total=total, interval=interval)
    times, counts = _check_common(pattern)
    # the pyramid truncates its last burst to land exactly on `total`
    assert arrival.total_workflows(pattern) == total
    # strictly increasing emission times, one `interval` apart
    assert all(b - a == pytest.approx(interval)
               for a, b in zip(times, times[1:]))
    # the ramp flips direction on the first burst ≥ peak, so a burst can
    # overshoot the peak by at most step-1 (and never more)
    assert max(counts) <= start + peak_delta + step - 1


def test_paper_defaults_match_section_6_1_4():
    """The defaults reproduce the paper's workloads: 30/30/34 workflows."""
    assert arrival.total_workflows(arrival.constant()) == 30
    assert arrival.total_workflows(arrival.linear()) == 30
    assert arrival.total_workflows(arrival.pyramid()) == 34
    assert [n for _, n in arrival.pyramid()] == [2, 4, 6, 4, 2, 2, 4, 6, 4]
