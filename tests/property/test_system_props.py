"""Randomized engine/simulator invariants (requires hypothesis)."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.engine import EngineConfig, TimingConfig, run_experiment
from repro.workflows import WORKFLOW_BUILDERS

pytestmark = pytest.mark.tier1

FAST = EngineConfig(timing=TimingConfig(
    pod_startup_delay=1.0, cleanup_delay=1.0, duration_multiplier=1.0))


@settings(max_examples=12, deadline=None)
@given(
    kind=st.sampled_from(list(WORKFLOW_BUILDERS)),
    count=st.integers(min_value=1, max_value=6),
    allocator=st.sampled_from(["aras", "fcfs"]),
    seed=st.integers(min_value=0, max_value=10_000),
    batched=st.booleans(),
)
def test_simulator_invariants_random(kind, count, allocator, seed, batched):
    """For arbitrary workloads: no overcommit (checked inside the engine
    at every event), every workflow completes, utilization in [0, 1] —
    in both burst-batched and per-task allocation modes."""
    cfg = FAST.evolve(batch_allocation=batched)
    m = run_experiment(kind, [(0.0, count)], allocator, seed=seed,
                       config=cfg)
    assert len(m.workflow_durations) == count
    assert 0.0 <= m.avg_cpu_usage <= 1.0
    assert 0.0 <= m.avg_mem_usage <= 1.0
    for _, c, mm in m.usage_series:
        assert c <= 1.0 + 1e-9 and mm <= 1.0 + 1e-9
