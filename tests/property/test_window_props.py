"""Windowed-drain parity properties (requires hypothesis).

Three contracts for ``TimingConfig.batch_window``, each across both
allocators, both sequential-core backends, and both engine modes
(batched / per-task replay):

* **window=0 is the legacy drain** — on a single lockstep burst a
  positive window cannot change anything (same-timestamp folding is
  already maximal, and every later allocatable event is guarded by the
  capacity event that produced it), so every metric matches
  ``batch_window=0`` bit for bit; and on all-distinct jittered arrivals
  ``batch_window=0`` decides one dispatch per arrival timestamp, each
  stamped at its own arrival — the seed engine's
  one-dispatch-per-event-timestamp contract.  (Across *multiple* bursts
  a window larger than the inter-burst gap deliberately folds the next
  burst's arrivals into the current decision — that is the decide-at-t+ε
  semantics, not a parity bug — so the invariance claim is per-burst.)
* **batched ≡ replay under any window** — the windowed burst decided in
  one fused dispatch is bit-for-bit the row-at-a-time replay of the same
  burst, extending ``tests/test_batch_parity.py`` to positive windows.
* **insertion-order invariance** — arrivals folded into one window batch
  in timestamp order, regardless of submission order.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.engine import EngineConfig, KubeAdaptor, TimingConfig, \
    run_experiment  # noqa: E402
from repro.workflows.spec import TaskSpec, WorkflowSpec  # noqa: E402

pytestmark = pytest.mark.tier1

FAST = EngineConfig(timing=TimingConfig(
    pod_startup_delay=1.0, cleanup_delay=1.0, duration_multiplier=1.0))

_allocator = st.sampled_from(["aras", "fcfs"])
_backend = st.sampled_from(["scan", "pallas"])
_batched = st.booleans()


def _metrics_equal(a, b):
    assert a.makespan == b.makespan
    assert a.workflow_durations == b.workflow_durations
    assert a.alloc_trace == b.alloc_trace
    assert a.oom_events == b.oom_events
    assert a.realloc_events == b.realloc_events
    assert a.num_allocations == b.num_allocations
    assert a.usage_series == b.usage_series


def _single_task_wf(i, duration=60.0):
    # Twin of tests/test_events.py::_single_task_wf — keep the task
    # shape in sync (duration far beyond every test's arrival span, so
    # completions never interrupt the drained windows).
    task = TaskSpec(task_id="t0", image="i", cpu=600.0, mem=1200.0,
                    duration=duration, min_cpu=100.0, min_mem=200.0)
    return WorkflowSpec(workflow_id=f"w{i}", tasks={"t0": task}, edges=[])


def _run_times(times, config, order=None):
    eng = KubeAdaptor(config)
    for i in (order if order is not None else range(len(times))):
        eng.submit(_single_task_wf(i), times[i])
    return eng.run()


@settings(max_examples=8, deadline=None)
@given(allocator=_allocator, backend=_backend, batched=_batched,
       window=st.floats(min_value=0.0, max_value=1e6, allow_nan=False),
       seed=st.integers(0, 1000))
def test_lockstep_burst_is_window_invariant(allocator, backend, batched,
                                            window, seed):
    """On a single same-timestamp burst any batch_window is bit-for-bit
    the batch_window=0 drain — i.e. window=0 IS the lockstep legacy
    semantics, in every allocator × backend × mode combination."""
    def run(w):
        cfg = FAST.evolve(alloc_backend=backend, batch_allocation=batched,
                          batch_window=w)
        return run_experiment("montage", [(0.0, 4)], allocator, seed=seed,
                              config=cfg)

    _metrics_equal(run(0.0), run(window))


@settings(max_examples=8, deadline=None)
@given(allocator=_allocator, backend=_backend, batched=_batched,
       gaps=st.lists(st.floats(min_value=0.25, max_value=5.0,
                               allow_nan=False), min_size=1, max_size=5),
       )
def test_window_zero_decides_each_arrival_alone(allocator, backend,
                                                batched, gaps):
    """batch_window=0 on all-distinct arrival timestamps: every arrival
    is its own decision, stamped at its own arrival time."""
    times, t = [], 0.0
    for gap in gaps:
        times.append(t)
        t += gap
    cfg = FAST.evolve(allocator=allocator, alloc_backend=backend,
                      batch_allocation=batched, batch_window=0.0)
    m = _run_times(times, cfg)
    assert m.num_allocations == len(times)
    assert m.num_dispatches == len(times)
    assert [ts for ts, *_ in m.alloc_trace] == times


@settings(max_examples=8, deadline=None)
@given(allocator=_allocator, backend=_backend,
       window=st.floats(min_value=0.0, max_value=30.0, allow_nan=False),
       seed=st.integers(0, 1000), count=st.integers(2, 4))
def test_windowed_batched_equals_replay(allocator, backend, window, seed,
                                        count):
    """The windowed fused dispatch ≡ its per-task replay, bit for bit,
    under stochastic (jittered) arrivals and any window."""
    import numpy as np

    rng = np.random.default_rng(seed)
    pattern = [(float(t), 1)
               for t in np.sort(rng.uniform(0.0, 20.0, count))]

    def run(batched):
        cfg = FAST.evolve(alloc_backend=backend, batch_allocation=batched,
                          batch_window=window)
        return run_experiment("montage", pattern, allocator, seed=seed,
                              config=cfg)

    _metrics_equal(run(True), run(False))


@settings(max_examples=8, deadline=None)
@given(allocator=_allocator, batched=_batched,
       times=st.lists(st.floats(min_value=0.0, max_value=20.0,
                                allow_nan=False),
                      min_size=2, max_size=6, unique=True),
       order_seed=st.integers(0, 1000))
def test_windowed_results_invariant_to_insertion_order(allocator, batched,
                                                       times, order_seed):
    """Arrivals within one window fold in timestamp order: submitting
    the same workflows in any order yields identical results."""
    import numpy as np

    times = sorted(times)
    cfg = FAST.evolve(allocator=allocator, batch_allocation=batched,
                      batch_window=25.0)
    order = np.random.default_rng(order_seed).permutation(len(times))
    _metrics_equal(_run_times(times, cfg),
                   _run_times(times, cfg, order=[int(i) for i in order]))
