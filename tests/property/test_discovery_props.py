"""Property tests for Alg. 2 discovery + Alg. 1 window accumulation.

Requires the optional ``hypothesis`` dependency (``pip install
.[test]``); the whole module skips cleanly on a bare jax+pytest
environment.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import discovery, lifecycle
from repro.core.types import ClusterSnapshot, TaskWindow

pytestmark = pytest.mark.tier1


def make_snapshot(num_nodes, pod_node, pod_cpu, pod_mem, pod_active,
                  cap_cpu=8000.0, cap_mem=16000.0):
    return ClusterSnapshot(
        allocatable_cpu=np.full((num_nodes,), cap_cpu, np.float32),
        allocatable_mem=np.full((num_nodes,), cap_mem, np.float32),
        pod_node=np.asarray(pod_node, np.int32),
        pod_cpu=np.asarray(pod_cpu, np.float32),
        pod_mem=np.asarray(pod_mem, np.float32),
        pod_active=np.asarray(pod_active, bool),
    )


@settings(max_examples=100, deadline=None)
@given(
    num_nodes=st.integers(min_value=1, max_value=16),
    pods=st.lists(
        st.tuples(
            st.integers(min_value=0, max_value=15),
            st.floats(min_value=0, max_value=4000),
            st.floats(min_value=0, max_value=8000),
            st.booleans(),
        ),
        max_size=64,
    ),
)
def test_discovery_matches_loop_oracle(num_nodes, pods):
    """Vectorized segment-sum == the paper's O(m·p) double loop."""
    pods = [(n % num_nodes, c, m, a) for (n, c, m, a) in pods]
    snap = make_snapshot(
        num_nodes,
        [p[0] for p in pods] or np.zeros((0,), np.int32),
        [p[1] for p in pods] or np.zeros((0,), np.float32),
        [p[2] for p in pods] or np.zeros((0,), np.float32),
        [p[3] for p in pods] or np.zeros((0,), bool),
    )
    rc, rm = discovery.discover(snap)
    for v in range(num_nodes):  # the Go loop, literally
        node_req_cpu = sum(c for (n, c, _, a) in pods if n == v and a)
        node_req_mem = sum(m for (n, _, m, a) in pods if n == v and a)
        assert float(rc[v]) == pytest.approx(8000.0 - node_req_cpu, rel=1e-4, abs=1e-2)
        assert float(rm[v]) == pytest.approx(16000.0 - node_req_mem, rel=1e-4, abs=1e-2)


@settings(max_examples=100, deadline=None)
@given(
    starts=st.lists(st.floats(min_value=0, max_value=100), min_size=1, max_size=32),
    w0=st.floats(min_value=0, max_value=100),
    dur=st.floats(min_value=0.1, max_value=50),
)
def test_window_demand_matches_oracle(starts, w0, dur):
    n = len(starts)
    cpu_arr = np.arange(1, n + 1, dtype=np.float32) * 10
    mem_arr = np.arange(1, n + 1, dtype=np.float32)
    win = TaskWindow(np.asarray(starts, np.float32), cpu_arr, mem_arr,
                     np.zeros((n,), bool))
    cpu, mem = lifecycle.window_demand(win, w0, w0 + dur, 7.0, 3.0)
    starts32 = np.asarray(starts, np.float32)
    lo, hi = np.float32(w0), np.float32(w0) + np.float32(dur)
    mask = (starts32 >= lo) & (starts32 < hi)
    assert cpu == pytest.approx(7.0 + float(cpu_arr[mask].sum()), rel=1e-5)
    assert mem == pytest.approx(3.0 + float(mem_arr[mask].sum()), rel=1e-5)
