"""Properties of the typed Scenario-API configs.

Under hypothesis-drawn field values: (a) ``EngineConfig`` and
``Scenario`` survive a JSON round-trip as *equal* dataclasses (the
serialized form is the spec, so nothing may be lost or coerced); (b)
``evolve()`` routes any subset of flat names into the right sub-configs
(the constructor shim is retired; ``evolve()`` is the flat spelling).
"""
import dataclasses

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

from repro.api import (  # noqa: E402
    AllocatorConfig,
    ClusterConfig,
    EngineConfig,
    FaultConfig,
    ForecastConfig,
    Scenario,
    TimingConfig,
    VerticalConfig,
)
from repro.api.config import _FLAT_MAP  # noqa: E402

pytestmark = pytest.mark.tier1

_pos = st.floats(min_value=0.5, max_value=1e6,
                 allow_nan=False, allow_infinity=False)

_cluster = st.builds(
    ClusterConfig,
    num_nodes=st.integers(min_value=1, max_value=4096),
    node_cpu=_pos,
    node_mem=_pos,
    num_clusters=st.integers(min_value=1, max_value=8),
    sharding=st.sampled_from(["auto", "off", "force"]),
)
_alloc = st.builds(
    AllocatorConfig,
    algorithm=st.sampled_from(["aras", "fcfs"]),
    alpha=st.floats(min_value=0.01, max_value=1.0, allow_nan=False),
    beta=st.floats(min_value=0.0, max_value=1e3, allow_nan=False),
    placement=st.sampled_from(["worst_fit", "best_fit", "first_fit",
                               "balanced"]),
    backend=st.sampled_from(["auto", "scan", "pallas"]),
    batch_allocation=st.booleans(),
    incremental_state=st.booleans(),
)
_timing = st.builds(
    TimingConfig,
    pod_startup_delay=_pos,
    cleanup_delay=_pos,
    restart_delay=_pos,
    oom_fraction=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
    duration_multiplier=_pos,
    max_time=_pos,
)
_faults = st.builds(
    FaultConfig,
    schedule=st.sampled_from(["none", "node_crash", "node_flap",
                              "oom_storm"]),
    params=st.dictionaries(
        st.sampled_from(["at", "seed"]),
        st.one_of(st.integers(min_value=0, max_value=100)), max_size=2),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    max_retries=st.one_of(st.none(),
                          st.integers(min_value=0, max_value=10)),
    backoff_base=st.floats(min_value=0.0, max_value=1e3, allow_nan=False),
    backoff_factor=st.floats(min_value=1.0, max_value=10.0,
                             allow_nan=False),
    workflow_timeout=st.one_of(st.none(), _pos),
)
# history/min_history must exceed the feature window, so the window is
# drawn first and the dependent fields derive their floor from it.
_forecast = st.integers(min_value=1, max_value=8).flatmap(
    lambda w: st.builds(
        ForecastConfig,
        enabled=st.booleans(),
        history=st.integers(min_value=w + 1, max_value=256),
        window=st.just(w),
        hidden=st.integers(min_value=1, max_value=64),
        lr=st.floats(min_value=1e-4, max_value=1.0, allow_nan=False),
        train_every=st.integers(min_value=1, max_value=8),
        min_history=st.integers(min_value=w + 1, max_value=256),
        window_scale=st.floats(min_value=0.1, max_value=4.0,
                               allow_nan=False),
        max_window=st.floats(min_value=0.0, max_value=60.0,
                             allow_nan=False),
        horizon=st.floats(min_value=0.0, max_value=600.0,
                          allow_nan=False),
        ghost_cap=st.floats(min_value=0.0, max_value=2.0,
                            allow_nan=False),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    ))
_vertical = st.builds(
    VerticalConfig,
    enabled=st.booleans(),
    check_interval=_pos,
    shrink_margin=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
    grow_margin=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
    resize_on_oom=st.booleans(),
)
_engine = st.builds(EngineConfig, cluster=_cluster, alloc=_alloc,
                    timing=_timing, faults=_faults, forecast=_forecast,
                    vertical=_vertical,
                    invariant_checks=st.booleans())

_scenario = st.builds(
    Scenario,
    name=st.text(min_size=1, max_size=20),
    workflows=st.lists(
        st.sampled_from(["montage", "epigenomics", "cybershake", "ligo"]),
        min_size=1, max_size=4, unique=True).map(tuple),
    arrival=st.sampled_from(["constant", "linear", "pyramid"]),
    arrival_params=st.dictionaries(
        st.sampled_from(["interval"]), _pos, max_size=1),
    engine=_engine,
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    task_kwargs=st.one_of(
        st.none(),
        st.dictionaries(st.sampled_from(["cpu", "mem", "min_cpu",
                                         "min_mem"]), _pos, max_size=4),
    ),
)


@given(cfg=_engine)
def test_engine_config_json_round_trip(cfg):
    assert EngineConfig.from_json(cfg.to_json()) == cfg


@given(sc=_scenario)
def test_scenario_json_round_trip(sc):
    assert Scenario.from_json(sc.to_json()) == sc


@given(cfg=_engine, keys=st.sets(st.sampled_from(sorted(_FLAT_MAP))))
def test_evolve_routes_any_flat_key_subset(cfg, keys):
    """Any subset of flat evolve() names == the same values routed
    through the composed sub-configs."""
    flat = {}
    for key in keys:
        part, field = _FLAT_MAP[key]
        flat[key] = getattr(getattr(cfg, part), field)
    parts = {"cluster": ClusterConfig(), "alloc": AllocatorConfig(),
             "timing": TimingConfig(), "faults": FaultConfig(),
             "forecast": ForecastConfig(), "vertical": VerticalConfig()}
    for key, value in flat.items():
        part, field = _FLAT_MAP[key]
        parts[part] = dataclasses.replace(parts[part], **{field: value})
    composed = EngineConfig(invariant_checks=cfg.invariant_checks, **parts)
    evolved = EngineConfig(
        invariant_checks=cfg.invariant_checks).evolve(**flat)
    assert evolved == composed
