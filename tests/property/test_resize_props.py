"""Stateful fuzz of in-place resize — conservation under random walks.

Extends the ``ClusterSim`` lifecycle fuzz with a ``resize`` rule: random
bind / resize / finish / delete sequences over single- and two-cluster
simulators, with an independent model of every live pod's quota.  The
invariant is *conservation*: the float64 books equal the model's
per-node quota sums at every step — no capacity leaks through a
shrink/grow, and what a resized pod releases at ``finish`` is exactly
what the books carried for it.  Quotas are floored to quarter-unit
granularity (dyadic, float32-exact), so equality is checked tight.

The hypothesis machine is the thorough driver; a seeded ``random`` walk
below replays the same rule mix so the conservation property still runs
where hypothesis is not installed.
"""
import random

import numpy as np
import pytest

from repro.cluster.simulator import ClusterSim
from repro.core.types import Allocation, PodPhase, TaskSpec

try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (
        RuleBasedStateMachine,
        initialize,
        invariant,
        precondition,
        rule,
    )
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.tier1

_TASK = TaskSpec(task_id="rz", image="i", cpu=1.0, mem=1.0,
                 duration=1.0, min_cpu=1.0, min_mem=1.0)


def _quarter(x: float) -> float:
    return float(np.floor(max(x, 0.0) * 4) / 4)


class _Model:
    """Shared rule bodies: an independent ledger of every live quota."""

    def setup(self, num_nodes, num_clusters, node_cpu, node_mem):
        self.sim = ClusterSim(num_nodes, node_cpu, node_mem,
                              num_clusters=min(num_clusters, num_nodes))
        self.now = 0.0
        self.quota = {}     # uid -> (node, cpu, mem): the model's books
        self.terminal = []

    def _free(self, node):
        used_c = sum(c for n, c, _ in self.quota.values() if n == node)
        used_m = sum(m for n, _, m in self.quota.values() if n == node)
        return (self.sim._alloc_cpu[node] - used_c,
                self.sim._alloc_mem[node] - used_m)

    def do_bind(self, node_pick, cpu_frac, mem_frac):
        node = node_pick % self.sim.num_nodes
        free_cpu, free_mem = self._free(node)
        alloc = Allocation(cpu=_quarter(free_cpu * cpu_frac),
                           mem=_quarter(free_mem * mem_frac),
                           node=node, feasible=True)
        pod = self.sim.bind(_TASK, alloc, self.now)
        self.quota[pod.uid] = (node, alloc.cpu, alloc.mem)
        self.now += 1.0

    def do_resize(self, pick, cpu_frac, mem_frac):
        """Resize a running pod anywhere between zero and quota + the
        node's free capacity — shrinks and grows in one rule, never an
        overcommit, so every raise would be a bug."""
        uid = sorted(self.quota)[pick % len(self.quota)]
        node, cpu, mem = self.quota[uid]
        free_cpu, free_mem = self._free(node)
        new_cpu = _quarter((cpu + free_cpu) * cpu_frac)
        new_mem = _quarter((mem + free_mem) * mem_frac)
        old = self.sim.resize(uid, new_cpu, new_mem)
        assert (old.cpu, old.mem) == (cpu, mem)  # returns the prior quota
        pod = self.sim.pods[uid]
        assert pod.resized and (pod.quota.cpu, pod.quota.mem) == \
            (new_cpu, new_mem)
        self.quota[uid] = (node, new_cpu, new_mem)

    def do_finish(self, pick, phase):
        uid = sorted(self.quota)[pick % len(self.quota)]
        self.sim.finish(uid, self.now, phase)
        del self.quota[uid]
        self.terminal.append(uid)
        self.now += 1.0

    def do_delete(self, pick):
        self.sim.delete(self.terminal.pop(pick % len(self.terminal)))

    def check_conservation(self):
        self.sim.check_invariants()
        want_cpu = np.zeros(self.sim.num_nodes)
        want_mem = np.zeros(self.sim.num_nodes)
        for node, cpu, mem in self.quota.values():
            want_cpu[node] += cpu
            want_mem[node] += mem
        assert np.allclose(self.sim._used_cpu, want_cpu, atol=1e-6), \
            (self.sim._used_cpu, want_cpu)
        assert np.allclose(self.sim._used_mem, want_mem, atol=1e-6)
        assert np.isclose(self.sim._used_cpu_total, want_cpu.sum(),
                          atol=1e-6)
        assert np.isclose(self.sim._used_mem_total, want_mem.sum(),
                          atol=1e-6)


if HAVE_HYPOTHESIS:
    class ResizeConservationMachine(_Model, RuleBasedStateMachine):
        @initialize(num_nodes=st.integers(1, 6),
                    num_clusters=st.integers(1, 2),
                    node_cpu=st.sampled_from([800.0, 6800.0]),
                    node_mem=st.sampled_from([1600.0, 13600.0]))
        def setup(self, num_nodes, num_clusters, node_cpu, node_mem):
            _Model.setup(self, num_nodes, num_clusters, node_cpu, node_mem)

        @rule(node_pick=st.integers(0, 10**6),
              cpu_frac=st.floats(0.0, 1.0, allow_nan=False),
              mem_frac=st.floats(0.0, 1.0, allow_nan=False))
        def bind(self, node_pick, cpu_frac, mem_frac):
            self.do_bind(node_pick, cpu_frac, mem_frac)

        @precondition(lambda self: self.quota)
        @rule(pick=st.integers(0, 10**6),
              cpu_frac=st.floats(0.0, 1.0, allow_nan=False),
              mem_frac=st.floats(0.0, 1.0, allow_nan=False))
        def resize(self, pick, cpu_frac, mem_frac):
            self.do_resize(pick, cpu_frac, mem_frac)

        @precondition(lambda self: self.quota)
        @rule(pick=st.integers(0, 10**6),
              phase=st.sampled_from([PodPhase.SUCCEEDED, PodPhase.FAILED]))
        def finish(self, pick, phase):
            self.do_finish(pick, phase)

        @precondition(lambda self: self.terminal)
        @rule(pick=st.integers(0, 10**6))
        def delete(self, pick):
            self.do_delete(pick)

        @invariant()
        def books_equal_model(self):
            if hasattr(self, "sim"):  # before @initialize
                self.check_conservation()

    ResizeConservationMachine.TestCase.settings = settings(
        max_examples=20, stateful_step_count=40, deadline=None)

    TestResizeConservation = ResizeConservationMachine.TestCase


@pytest.mark.parametrize("num_clusters", [1, 2])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_seeded_walk_conserves_capacity(seed, num_clusters):
    """Deterministic replay of the machine's rule mix: 200 random
    bind/resize/finish/delete steps, conservation checked after each."""
    rng = random.Random(seed * 7 + num_clusters)
    m = _Model()
    m.setup(num_nodes=rng.randint(2, 6), num_clusters=num_clusters,
            node_cpu=rng.choice([800.0, 6800.0]),
            node_mem=rng.choice([1600.0, 13600.0]))
    for _ in range(200):
        op = rng.random()
        if op < 0.35 or not m.quota:
            m.do_bind(rng.randrange(10**6), rng.random(), rng.random())
        elif op < 0.75:
            m.do_resize(rng.randrange(10**6), rng.random(), rng.random())
        elif op < 0.9:
            m.do_finish(rng.randrange(10**6),
                        rng.choice([PodPhase.SUCCEEDED, PodPhase.FAILED]))
        elif m.terminal:
            m.do_delete(rng.randrange(10**6))
        m.check_conservation()
    while m.quota:
        m.do_finish(0, PodPhase.SUCCEEDED)
        m.check_conservation()
    assert m.sim._used_cpu_total == 0.0 and m.sim._used_mem_total == 0.0


# ------------------------------------------------- direct edge cases

def _one_pod_sim():
    sim = ClusterSim(2, 1000.0, 2000.0)
    pod = sim.bind(_TASK, Allocation(cpu=400.0, mem=800.0, node=0,
                                     feasible=True), 0.0)
    return sim, pod


def test_resize_rejects_negative_quota():
    sim, pod = _one_pod_sim()
    with pytest.raises(RuntimeError, match="negative"):
        sim.resize(pod.uid, -1.0, 800.0)


def test_resize_rejects_overcommit():
    sim, pod = _one_pod_sim()
    with pytest.raises(RuntimeError):
        sim.resize(pod.uid, 5000.0, 800.0)


def test_resize_to_zero_then_finish_is_clean():
    """The books survive the degenerate shrink-to-nothing and release
    exactly nothing at finish."""
    sim, pod = _one_pod_sim()
    sim.resize(pod.uid, 0.0, 0.0)
    assert sim._used_cpu[0] == 0.0 and sim._used_mem[0] == 0.0
    sim.finish(pod.uid, 1.0, PodPhase.SUCCEEDED)
    sim.check_invariants()
    assert sim._used_cpu_total == 0.0 and sim._used_mem_total == 0.0


def test_node_headroom_tracks_resize_and_offline():
    sim, pod = _one_pod_sim()
    head = sim.node_headroom(0)
    assert head.cpu == 600.0 and head.mem == 1200.0
    sim.resize(pod.uid, 100.0, 200.0)
    head = sim.node_headroom(0)
    assert head.cpu == 900.0 and head.mem == 1800.0
    sim.finish(pod.uid, 1.0, PodPhase.SUCCEEDED)
    sim.set_node_down(0, 2.0)
    assert sim.node_headroom(0) == type(head)(0.0, 0.0)
