"""Property tests for Algorithm 3 + Eq. 9 (requires hypothesis)."""
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.evaluation import EvalInputs, evaluate

pytestmark = pytest.mark.tier1

ALPHA = 0.8


def ev(task_cpu, task_mem, req_cpu, req_mem, tot_cpu, tot_mem, remax_cpu, remax_mem):
    return evaluate(
        EvalInputs(
            jnp.float32(task_cpu), jnp.float32(task_mem),
            jnp.float32(req_cpu), jnp.float32(req_mem),
            jnp.float32(tot_cpu), jnp.float32(tot_mem),
            jnp.float32(remax_cpu), jnp.float32(remax_mem),
        ),
        ALPHA,
    )


def cuts(task_cpu, task_mem, req_cpu, req_mem, tot_cpu, tot_mem):
    return task_cpu * tot_cpu / req_cpu, task_mem * tot_mem / req_mem


pos = st.floats(min_value=1.0, max_value=1e6, allow_nan=False, allow_infinity=False)


@settings(max_examples=200, deadline=None)
@given(task_cpu=pos, task_mem=pos, extra_cpu=pos, extra_mem=pos,
       tot_cpu=pos, tot_mem=pos, frac=st.floats(min_value=0.01, max_value=1.0))
def test_allocation_invariants(task_cpu, task_mem, extra_cpu, extra_mem,
                               tot_cpu, tot_mem, frac):
    """Invariants of Alg. 3 that hold for ALL inputs:

    1. allocations are strictly positive;
    2. the CPU grant never exceeds max(request, α·Re_max, cpu_cut) — i.e.
       the evaluator never invents resources beyond its three sources;
    3. scenario-0 grants equal the request exactly.
    """
    remax_cpu, remax_mem = frac * tot_cpu, frac * tot_mem
    req_cpu, req_mem = task_cpu + extra_cpu, task_mem + extra_mem
    r = ev(task_cpu, task_mem, req_cpu, req_mem, tot_cpu, tot_mem,
           remax_cpu, remax_mem)
    cpu, mem = float(r.cpu), float(r.mem)
    cpu_cut, mem_cut = cuts(task_cpu, task_mem, req_cpu, req_mem, tot_cpu, tot_mem)

    assert cpu > 0 and mem > 0
    assert cpu <= max(task_cpu, ALPHA * remax_cpu, cpu_cut) * (1 + 1e-5)
    assert mem <= max(task_mem, ALPHA * remax_mem, mem_cut) * (1 + 1e-5)
    if req_cpu < tot_cpu and req_mem < tot_mem:
        if task_cpu < remax_cpu and task_mem < remax_mem:
            assert cpu == pytest.approx(task_cpu, rel=1e-5)
            assert mem == pytest.approx(task_mem, rel=1e-5)


@settings(max_examples=100, deadline=None)
@given(task_cpu=pos, task_mem=pos, mult=st.floats(min_value=1.5, max_value=100.0),
       tot_cpu=pos, tot_mem=pos)
def test_scaling_preserves_demand_ratio(task_cpu, task_mem, mult, tot_cpu, tot_mem):
    """Eq. 9: in the both-insufficient scenario the grant equals the
    request scaled by residual/demand — proportional fairness across
    competing in-window tasks."""
    req_cpu, req_mem = task_cpu * mult * 2, task_mem * mult * 2
    # force ¬A1 ∧ ¬A2
    tot_cpu = min(tot_cpu, req_cpu * 0.5)
    tot_mem = min(tot_mem, req_mem * 0.5)
    r = ev(task_cpu, task_mem, req_cpu, req_mem, tot_cpu, tot_mem,
           tot_cpu, tot_mem)
    assert int(r.scenario) == 3
    assert float(r.cpu) == pytest.approx(task_cpu * tot_cpu / req_cpu, rel=1e-4)
    assert float(r.mem) == pytest.approx(task_mem * tot_mem / req_mem, rel=1e-4)
