"""Hoisted base demand + triangular stamp correction ≡ per-step window.

The fused burst pipeline replaces the per-step O(T) ``masked_demand``
reduction with a hoisted ``[B, T]`` base (record table at pre-burst start
times) plus a ``[B, B]`` correction table consumed under the stamped-row
mask.  Property: for arbitrary record tables, windows and mid-burst stamp
sets, ``base[i] + Σ_j stamped[j]·delta[i, j]`` equals the per-step
``masked_demand`` evaluated against the *updated* record table (stamped
records moved to ``t_start = now``) — up to float32 re-association, since
the decomposition deliberately regroups the sum.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import lifecycle  # noqa: E402
from repro.core.allocator import _burst_precompute  # noqa: E402

pytestmark = pytest.mark.tier1

_f = st.floats(min_value=0.0, max_value=100.0, allow_nan=False,
               allow_infinity=False, width=32)


@st.composite
def _burst_case(draw):
    num_rec = draw(st.integers(1, 12))
    num_rows = draw(st.integers(1, 6))
    rec_t = draw(st.lists(_f, min_size=num_rec, max_size=num_rec))
    rec_cpu = draw(st.lists(_f, min_size=num_rec, max_size=num_rec))
    rec_mem = draw(st.lists(_f, min_size=num_rec, max_size=num_rec))
    rec_done = draw(st.lists(st.booleans(), min_size=num_rec,
                             max_size=num_rec))
    now = draw(_f)
    wend = draw(st.lists(_f, min_size=num_rows, max_size=num_rows))
    b_cpu = draw(st.lists(_f, min_size=num_rows, max_size=num_rows))
    b_mem = draw(st.lists(_f, min_size=num_rows, max_size=num_rows))
    # Unique record slots (or -1) per row — slots are unique in a burst.
    slot_pool = draw(st.permutations(list(range(num_rec))))
    has_slot = draw(st.lists(st.booleans(), min_size=num_rows,
                             max_size=num_rows))
    b_self, k = [], 0
    for flag in has_slot:
        if flag and k < num_rec:
            b_self.append(slot_pool[k])
            k += 1
        else:
            b_self.append(-1)
    stamped = draw(st.lists(st.booleans(), min_size=num_rows,
                            max_size=num_rows))
    stamped = [s and b_self[j] >= 0 for j, s in enumerate(stamped)]
    return (np.array(rec_t, np.float32), np.array(rec_cpu, np.float32),
            np.array(rec_mem, np.float32), np.array(rec_done, bool),
            np.float32(now), np.array(wend, np.float32),
            np.array(b_cpu, np.float32), np.array(b_mem, np.float32),
            np.array(b_self, np.int32), np.array(stamped, bool))


@given(_burst_case())
@settings(max_examples=80, deadline=None)
def test_hoisted_decomposition_matches_per_step_masked_demand(case):
    (rec_t, rec_cpu, rec_mem, rec_done, now, wend, b_cpu, b_mem,
     b_self, stamped) = case
    num_rec = rec_t.shape[0]
    num_rows = wend.shape[0]
    ones = np.ones((num_rec,), np.float32)  # stand-in residuals/caps
    (_, _, _, _, _, _, base_c, base_m, dlt_c, dlt_m) = _burst_precompute(
        jnp.asarray(ones), jnp.asarray(ones), jnp.asarray(ones),
        jnp.asarray(ones),
        jnp.asarray(rec_t), jnp.asarray(rec_cpu), jnp.asarray(rec_mem),
        jnp.asarray(rec_done),
        jnp.asarray(b_cpu), jnp.asarray(b_mem), jnp.asarray(wend),
        jnp.asarray(b_self), jnp.asarray(now), mode="aras",
    )
    stamped_f = stamped.astype(np.float32)
    got_c = np.asarray(base_c) + np.asarray(dlt_c) @ stamped_f
    got_m = np.asarray(base_m) + np.asarray(dlt_m) @ stamped_f

    # Oracle: the record table as the sequential loop would see it —
    # stamped records actually started at ``now``.
    t_upd = rec_t.copy()
    for j in range(num_rows):
        if stamped[j]:
            t_upd[b_self[j]] = now
    slot_ids = jnp.arange(num_rec, dtype=jnp.int32)
    for i in range(num_rows):
        want_c, want_m = lifecycle.masked_demand(
            jnp.asarray(t_upd), jnp.asarray(rec_cpu), jnp.asarray(rec_mem),
            jnp.asarray(rec_done), slot_ids,
            jnp.asarray(now), jnp.asarray(wend[i]),
            jnp.asarray(b_cpu[i]), jnp.asarray(b_mem[i]),
            jnp.asarray(b_self[i]),
        )
        np.testing.assert_allclose(got_c[i], float(want_c), rtol=1e-5,
                                   atol=1e-2)
        np.testing.assert_allclose(got_m[i], float(want_m), rtol=1e-5,
                                   atol=1e-2)
