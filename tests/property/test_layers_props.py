"""Property tests for MoE routing layers (requires hypothesis)."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.models import layers as L
from repro.models.config import ModelConfig, MoEConfig

pytestmark = pytest.mark.slow


def moe_cfg(dispatch="scatter", cf=1.25, k=2, E=8, shared=0):
    return ModelConfig(
        name="t", num_layers=1, d_model=32, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=128,
        moe=MoEConfig(num_experts=E, top_k=k, expert_d_ff=48,
                      num_shared_experts=shared, capacity_factor=cf,
                      dispatch_mode=dispatch))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 1000),
    k=st.integers(1, 4),
    cf=st.floats(0.5, 4.0),
    T=st.sampled_from([8, 16, 24]),
)
def test_scatter_equals_einsum_dispatch(seed, k, cf, T):
    """The two dispatch modes are the same function (property)."""
    cfg_e = moe_cfg("einsum", cf=cf, k=k)
    cfg_s = moe_cfg("scatter", cf=cf, k=k)
    p = L.init_moe(jax.random.key(0), cfg_e)
    x = jax.random.normal(jax.random.key(seed), (2, T, 32))
    ye, auxe = L.moe(p, cfg_e, x)
    ys, auxs = L.moe(p, cfg_s, x)
    np.testing.assert_allclose(np.asarray(ye), np.asarray(ys),
                               atol=1e-4, rtol=1e-4)
    assert abs(float(auxe.dropped_fraction) -
               float(auxs.dropped_fraction)) < 1e-6


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), E=st.sampled_from([4, 8, 16]),
       T=st.integers(2, 64), k=st.integers(1, 4))
def test_positions_by_sort_is_exclusive_count(seed, E, T, k):
    """pos[t,j] == number of earlier (token-major) pairs routed to the
    same expert — the exclusive-cumsum definition."""
    eidx = jax.random.randint(jax.random.key(seed), (T, k), 0, E)
    pos = np.asarray(L._positions_by_sort(eidx, E))
    e = np.asarray(eidx).reshape(-1)
    expected = np.zeros_like(e)
    seen = {}
    for i, ei in enumerate(e):
        expected[i] = seen.get(int(ei), 0)
        seen[int(ei)] = expected[i] + 1
    np.testing.assert_array_equal(pos.reshape(-1), expected)
