"""Vertical adaptivity (ARC-V): usage curves + in-place pod resize.

Four contracts:

* **inert when disabled** — attaching usage curves to a workload and
  leaving ``VerticalConfig.enabled=False`` is bit-for-bit the engine
  without them, offline and streaming: the curves only describe what the
  pods *would* consume, the controller is the only reader.
* **shrink conservation** — capacity reclaimed by shrinking an
  over-provisioned running pod re-admits a previously-refused pending
  task strictly earlier than the baseline that waits for completion.
* **resize-first OOM** — a pod admitted below its runtime memory floor
  is grown in place when the node has headroom; the §6.2.2 kill (and its
  restart penalty) only happens when it does not.
* **chaos interaction** — a displaced *resized* pod re-enters admission
  at its current (controller-sized) quota, not the stale declared
  request.
"""
import dataclasses

import pytest

from repro.api import (
    CURVES,
    EngineConfig,
    Scenario,
    TimingConfig,
    VerticalConfig,
    grid,
    run_scenario,
)
from repro.engine import KubeAdaptor
from repro.engine.events import EventKind
from repro.serving import StreamEngine
from repro.vertical import attach_usage, peak_usage, usage_at
from repro.workflows.spec import TaskSpec, WorkflowSpec

pytestmark = pytest.mark.tier1


# ------------------------------------------------------- usage curves

def _one_task_wf(i=0, cpu=1000.0, mem=2000.0, duration=10.0,
                 min_cpu=100.0, min_mem=200.0, **kw) -> WorkflowSpec:
    t = TaskSpec(task_id="t0", image="img", cpu=cpu, mem=mem,
                 duration=duration, min_cpu=min_cpu, min_mem=min_mem, **kw)
    return WorkflowSpec(workflow_id=f"w{i}", tasks={"t0": t}, edges=[])


def test_curve_registry_bootstraps():
    assert set(CURVES.names()) >= {"constant", "ramp", "step", "bursty"}


@pytest.mark.parametrize("curve,params", [
    ("constant", {"frac": 0.6}),
    ("ramp", {"start": 0.9, "end": 0.2}),
    ("ramp", {"start": 0.3, "end": 1.2}),   # fractions may exceed 1.0
    ("step", {"levels": (0.9, 0.35), "breaks": (0.4,)}),
    ("bursty", {"lo": 0.3, "hi": 0.9, "bursts": 3, "seed": 5}),
])
def test_peak_dominates_value_and_is_monotone(curve, params):
    """``peak(p0)`` is the max of ``value`` over the remaining lifetime:
    it dominates every later sample and never increases as p0 advances —
    the property that makes shrink-to-remaining-peak safe."""
    wf = attach_usage(_one_task_wf(), curve, params)
    task = wf.tasks["t0"]
    grid_p = [i / 50 for i in range(51)]
    peaks = [peak_usage(task, p)[0] for p in grid_p]
    for a, b in zip(peaks, peaks[1:]):
        assert a >= b - 1e-9
    for i, p0 in enumerate(grid_p):
        tail = max(usage_at(task, p)[0] for p in grid_p[i:])
        assert peaks[i] >= tail - 1e-6


def test_usage_scales_declared_request():
    wf = attach_usage(_one_task_wf(cpu=1000.0, mem=2000.0), "constant",
                      {"frac": 0.5})
    assert usage_at(wf.tasks["t0"], 0.3) == (500.0, 1000.0)
    assert peak_usage(wf.tasks["t0"], 0.0) == (500.0, 1000.0)


def test_bursty_is_seed_deterministic_and_per_task():
    tasks = {
        f"t{j}": TaskSpec(task_id=f"t{j}", image="i", cpu=100.0, mem=100.0,
                          duration=5.0, min_cpu=10.0, min_mem=10.0)
        for j in range(2)
    }
    spec = WorkflowSpec(workflow_id="w", tasks=tasks, edges=[])
    a = attach_usage(spec, "bursty", seed=7)
    b = attach_usage(spec, "bursty", seed=7)
    c = attach_usage(spec, "bursty", seed=8)
    assert a.tasks["t0"].usage_params == b.tasks["t0"].usage_params
    assert a.tasks["t0"].usage_params != a.tasks["t1"].usage_params
    assert a.tasks["t0"].usage_params != c.tasks["t0"].usage_params


def test_attach_usage_validates():
    with pytest.raises(ValueError, match="unknown usage curve"):
        attach_usage(_one_task_wf(), "nope")
    with pytest.raises(ValueError, match="rejects params"):
        attach_usage(_one_task_wf(), "ramp", {"bogus": 1.0})


def test_attach_usage_skips_virtual_tasks():
    wf = _one_task_wf(cpu=0.0, mem=0.0, min_cpu=0.0, min_mem=0.0)
    out = attach_usage(wf, "ramp")
    assert out.tasks["t0"].usage_curve is None


# ------------------------------------------------------------- config

def test_vertical_config_defaults_off_and_roundtrips():
    cfg = EngineConfig()
    assert cfg.vertical == VerticalConfig() and not cfg.vertical.enabled
    on = cfg.evolve(vertical=True, resize_interval=9.0, shrink_margin=0.2)
    assert on.vertical.enabled and on.vertical.check_interval == 9.0
    assert EngineConfig.from_json(on.to_json()) == on
    assert cfg.evolve(vertical=VerticalConfig(enabled=True)).vertical.enabled


def test_vertical_config_validates():
    with pytest.raises(ValueError):
        EngineConfig().evolve(vertical=True, resize_interval=0.0).validate()
    with pytest.raises(ValueError):
        EngineConfig().evolve(vertical=True, shrink_margin=-0.1).validate()


# ------------------------------------------- inert-when-disabled parity

_TIMING = TimingConfig(pod_startup_delay=1.0, cleanup_delay=1.0,
                       duration_multiplier=1.0, batch_window=3.0)


def _curved_arrivals():
    out = []
    for i in range(4):
        wf = _one_task_wf(i, cpu=600.0 + 50.0 * i, mem=1200.0,
                          duration=8.0 + i)
        out.append((1.5 * i, attach_usage(wf, "ramp",
                                          {"start": 0.9, "end": 0.3})))
    return out


def _assert_metrics_equal(a, b):
    assert a.alloc_trace == b.alloc_trace
    assert a.num_dispatches == b.num_dispatches
    assert a.num_allocations == b.num_allocations
    assert a.num_waits == b.num_waits
    assert a.makespan == b.makespan
    assert a.usage_series == b.usage_series
    assert a.workflow_durations == b.workflow_durations
    assert a.oom_events == b.oom_events
    assert a.resize_events == b.resize_events


def test_disabled_is_bit_for_bit_inert_offline():
    """Curves on the tasks + ``enabled=False`` ≡ no curves at all."""
    def run(arrivals, cfg):
        eng = KubeAdaptor(cfg)
        for t, wf in arrivals:
            eng.submit(wf, t)
        return eng.run()

    cfg = EngineConfig(timing=_TIMING)
    plain = [(t, _one_task_wf(i, cpu=600.0 + 50.0 * i, mem=1200.0,
                              duration=8.0 + i))
             for i, (t, _) in enumerate(_curved_arrivals())]
    a = run(_curved_arrivals(), cfg)
    b = run(plain, cfg)
    c = run(_curved_arrivals(), cfg.evolve(vertical=False))
    assert a.num_resizes == 0 and not a.resize_events
    _assert_metrics_equal(a, b)
    _assert_metrics_equal(a, c)


def test_disabled_is_bit_for_bit_inert_stream():
    cfg = EngineConfig(timing=_TIMING)
    offline = KubeAdaptor(cfg)
    for t, wf in _curved_arrivals():
        offline.submit(wf, t)
    want = offline.run()
    stats = StreamEngine(KubeAdaptor(cfg), _curved_arrivals()).serve()
    assert stats.metrics.num_resizes == 0
    _assert_metrics_equal(stats.metrics, want)


# --------------------------------------------------- shrink conservation

def _contended():
    """One node; A's ramp decays, B is refused until capacity appears."""
    a = attach_usage(_one_task_wf(0, cpu=3000.0, mem=3000.0, duration=100.0,
                                  min_cpu=100.0, min_mem=300.0),
                     "ramp", {"start": 0.9, "end": 0.2})
    b = _one_task_wf(1, cpu=2000.0, mem=2000.0, duration=10.0,
                     min_cpu=1800.0, min_mem=1800.0)
    return [(0.0, a), (1.0, b)]


def _contended_cfg(vertical: bool) -> EngineConfig:
    cfg = EngineConfig(timing=_TIMING).evolve(
        num_nodes=1, node_cpu=4000.0, node_mem=8000.0)
    if vertical:
        cfg = cfg.evolve(vertical=True, resize_interval=10.0)
    return cfg


def _run_contended(vertical: bool):
    eng = KubeAdaptor(_contended_cfg(vertical))
    for t, wf in _contended():
        eng.submit(wf, t)
    return eng.run()


def _bind_time(metrics, key):
    return min(t for (t, k, _cpu, _mem, _why) in metrics.alloc_trace
               if k == key)


def test_shrink_readmits_refused_pending_task_earlier():
    """The reclaimed quota is *conserved*: what the shrink frees, the
    same-time RETRY hands to the pending task the baseline kept refusing
    until the fat pod completed."""
    base = _run_contended(vertical=False)
    vert = _run_contended(vertical=True)
    assert base.num_waits >= 1          # B was refused at admission
    assert vert.num_shrinks >= 1
    assert vert.reclaimed_cpu_seconds > 0
    # baseline binds B only after A completes; vertical mid-A, off a shrink
    assert _bind_time(base, "w1/t0") > 100.0
    assert _bind_time(vert, "w1/t0") < _bind_time(base, "w1/t0")
    assert vert.makespan < base.makespan
    # A itself still runs to its full duration — shrink is invisible to it.
    assert vert.workflow_durations["w0"] == base.workflow_durations["w0"]


def test_trailing_resize_tick_does_not_stretch_makespan():
    """The controller re-arms every sweep; once no Running usage-curve
    pod remains the queued RESIZE is dropped before the clock advances,
    so an idle tick can never define the makespan."""
    vert = _run_contended(vertical=True)
    interval = _contended_cfg(True).vertical.check_interval
    assert vert.makespan % interval != 0.0 or vert.makespan < interval


# ---------------------------------------------------- resize-first OOM

def _oom_scenario(**engine_kw) -> Scenario:
    sc = Scenario(
        name="vert-oom", workflows=("montage",), arrival="constant",
        arrival_params={"y": 4, "bursts": 1},
        task_kwargs={"mem": 2600.0, "min_mem": 200.0,
                     "actual_min_mem": 2000.0},
        seed=1)
    if engine_kw:
        sc = dataclasses.replace(sc, engine=sc.engine.evolve(**engine_kw))
    return sc


def test_resize_first_avoids_the_baseline_oom():
    base = run_scenario(_oom_scenario())
    vert = run_scenario(_oom_scenario(vertical=True))
    assert base.num_oom_events >= 1
    assert vert.resizes_avoided_oom >= 1
    assert vert.num_oom_events < base.num_oom_events
    # grown in place: no kill, no restart round-trip, earlier finish
    assert vert.avg_total_duration < base.avg_total_duration


def test_resize_on_oom_gate():
    vert = run_scenario(_oom_scenario(vertical=True, resize_on_oom=False))
    base = run_scenario(_oom_scenario())
    assert vert.resizes_avoided_oom == 0
    assert vert.num_oom_events == base.num_oom_events


# ------------------------------------------------------- chaos crossing

def test_displaced_resized_pod_heals_at_current_quota():
    """Kill the node under a shrunken pod: the HEAL re-admission carries
    the controller's quota, not the stale declared request."""
    cfg = EngineConfig(timing=_TIMING).evolve(
        num_nodes=2, node_cpu=4000.0, node_mem=8000.0,
        vertical=True, resize_interval=10.0,
        fault_schedule="node_flap",
        fault_params={"at": 30.0, "down_for": 20.0, "nodes": 2})
    eng = KubeAdaptor(cfg)
    eng.submit(attach_usage(
        _one_task_wf(0, cpu=3000.0, mem=3000.0, duration=100.0,
                     min_cpu=100.0, min_mem=300.0),
        "ramp", {"start": 0.9, "end": 0.2}), 0.0)
    while not eng.metrics.displaced_tasks:
        eng.step()
    assert eng.metrics.num_shrinks >= 1  # it was resized before the crash
    heals = [e for e in eng.queue._heap if e.kind is EventKind.HEAL]
    assert len(heals) == 1
    _wf_id, heal_task = heals[0].payload
    shrunken = [(dc, dm) for _t, _key, dc, dm in eng.metrics.resize_events]
    assert heal_task.cpu == 3000.0 + sum(dc for dc, _ in shrunken)
    assert heal_task.mem == 3000.0 + sum(dm for _, dm in shrunken)
    assert heal_task.cpu < 3000.0 and heal_task.mem < 3000.0
    eng.run()  # node comes back; the shrunken re-admission completes
    assert eng.metrics.recovery_times and not eng.metrics.failed_workflows
    assert eng.metrics.workflow_durations


# ------------------------------------------------ scenario-level surface

def test_scenario_usage_curves_validate():
    with pytest.raises(ValueError, match="not in Scenario.workflows"):
        Scenario(workflows=("montage",),
                 usage_curves={"nope": "ramp"}).validate()
    with pytest.raises(ValueError, match="unknown usage curve"):
        Scenario(workflows=("montage",),
                 usage_curves={"montage": "zigzag"}).validate()
    with pytest.raises(ValueError, match="do not fit curve"):
        Scenario(workflows=("montage",),
                 usage_curves={"montage": {"curve": "ramp",
                                           "params": {"zig": 1}}}).validate()


def test_run_result_carries_reclaim_telemetry():
    sc = Scenario(
        name="vert", workflows=("montage",), arrival="constant",
        arrival_params={"y": 2, "bursts": 1},
        engine=EngineConfig().evolve(vertical=True, resize_interval=8.0),
        usage_curves={"montage": {"curve": "ramp",
                                  "params": {"start": 0.9, "end": 0.2}}},
        seed=3)
    r = run_scenario(sc)
    d = r.to_dict()
    for key in ("num_resizes", "num_shrinks", "num_grows",
                "resizes_avoided_oom", "reclaimed_cpu_seconds",
                "reclaimed_mem_seconds"):
        assert key in d
    assert r.num_resizes == r.num_shrinks + r.num_grows > 0
    assert r.reclaimed_cpu_seconds > 0 and r.reclaimed_mem_seconds > 0


def test_grid_fault_params_axis():
    base = Scenario(workflows=("montage",), arrival_params={"y": 1})
    plain = grid(base, allocators=("aras",), arrivals=("constant",))
    assert all("-f" not in s.name for s in plain)  # backward compatible
    g = grid(base, allocators=("aras",), arrivals=("constant",),
             fault_params=({"mtbf": 200.0},
                           {"mtbf": 400.0, "recovery_time": 15.0}))
    assert len(g) == 2 * len(plain)
    assert [s.name.rsplit("-", 1)[1] for s in g] == ["f0", "f1"]
    merged = [dict(s.engine.faults.params) for s in g]
    assert merged[0]["mtbf"] == 200.0 and "recovery_time" not in merged[0]
    assert merged[1] == {**merged[1], "mtbf": 400.0, "recovery_time": 15.0}
