"""Online arrival forecasting (repro.forecast) + predictive allocation.

Four contracts:

* **determinism** — the forecaster is a pure function of (config,
  observation sequence): same seed and same arrivals give the same
  predictions, fits and losses, run to run;
* **cold start** — until ``min_history`` gaps the forecaster abstains
  and both consumers fall back to the static configuration;
* **parity** — ``forecast.enabled=False`` is bit-for-bit today's
  engine (identical allocation trace, offline and streaming), no
  matter what the other forecast knobs say;
* **wiring** — the ``adaptive_scaling`` allocator is registered with
  the ``forecast`` capability, demands an enabled forecast config, and
  its scenario runs carry forecast telemetry on the ``RunResult``.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (
    ALLOCATORS,
    EngineConfig,
    ForecastConfig,
    Scenario,
    grid,
    run_scenario,
)
from repro.engine import KubeAdaptor
from repro.forecast import ArrivalForecaster

pytestmark = pytest.mark.tier1

_CFG = ForecastConfig(enabled=True, history=24, window=4, hidden=8,
                      min_history=6)


def _observe_trace(fc: ArrivalForecaster, gaps, cpu=100.0, mem=200.0):
    t = 0.0
    fc.observe(t, cpu, mem)
    for gap in gaps:
        t += float(gap)
        fc.observe(t, cpu, mem)
    return fc


def _bursty_gaps(n=40, seed=0):
    rng = np.random.default_rng(seed)
    # alternating quiet stretches and tight bursts
    return np.where(rng.random(n) < 0.5,
                    rng.exponential(0.5, n), rng.exponential(20.0, n))


# ---------------------------------------------------------- determinism

def test_same_seed_same_trace_same_predictions():
    gaps = _bursty_gaps()
    a = _observe_trace(ArrivalForecaster(_CFG), gaps)
    b = _observe_trace(ArrivalForecaster(_CFG), gaps)
    assert a.num_fits == b.num_fits > 0
    assert a.last_loss == b.last_loss
    assert a.predicted_gap() == b.predicted_gap()
    assert a.horizon_demand() == b.horizon_demand()


def test_prediction_sequence_is_reproducible():
    gaps = _bursty_gaps(seed=3)
    a, b = ArrivalForecaster(_CFG), ArrivalForecaster(_CFG)
    t = 0.0
    seq_a, seq_b = [], []
    for gap in np.concatenate([[0.0], gaps]):
        t += float(gap)
        a.observe(t, 10.0, 20.0)
        b.observe(t, 10.0, 20.0)
        seq_a.append(a.predicted_gap())
        seq_b.append(b.predicted_gap())
    assert seq_a == seq_b
    assert any(g is not None for g in seq_a)


def test_different_seed_different_params():
    gaps = _bursty_gaps()
    a = _observe_trace(ArrivalForecaster(_CFG), gaps)
    b = _observe_trace(
        ArrivalForecaster(dataclasses.replace(_CFG, seed=1)), gaps)
    assert a.predicted_gap() != b.predicted_gap()


# ------------------------------------------------------------ cold start

def test_abstains_until_min_history():
    fc = ArrivalForecaster(_CFG)
    t = 0.0
    for i in range(_CFG.min_history):  # min_history arrivals = min-1 gaps
        fc.observe(t, 1.0, 1.0)
        t += 5.0
        assert not fc.ready
        assert fc.predicted_gap() is None
        assert fc.fold_window(3.5) == 3.5  # static fallback
        assert fc.horizon_demand() == (0.0, 0.0)
    fc.observe(t, 1.0, 1.0)
    assert fc.ready
    assert fc.predicted_gap() is not None


def test_fold_window_scales_and_caps():
    cfg = dataclasses.replace(_CFG, window_scale=2.0, max_window=6.0)
    fc = _observe_trace(ArrivalForecaster(cfg), np.full(20, 5.0))
    gap = fc.predicted_gap()
    assert gap is not None and gap > 0.0
    assert fc.fold_window(0.0) == pytest.approx(min(2.0 * gap, 6.0))
    wide = dataclasses.replace(cfg, max_window=0.25)
    fc2 = _observe_trace(ArrivalForecaster(wide), np.full(20, 5.0))
    assert fc2.fold_window(0.0) == 0.25


def test_constant_gaps_predict_near_the_gap():
    """On a constant-rate stream the prediction lands near the true gap
    (the residual head starts at the running mean and trains toward it)."""
    fc = _observe_trace(ArrivalForecaster(_CFG), np.full(23, 7.0))
    assert fc.predicted_gap() == pytest.approx(7.0, rel=0.5)


def test_horizon_demand_tracks_rate_and_intensity():
    cfg = dataclasses.replace(_CFG, horizon=30.0)
    fc = _observe_trace(ArrivalForecaster(cfg), np.full(20, 5.0),
                        cpu=100.0, mem=400.0)
    cpu, mem = fc.horizon_demand()
    assert cpu > 0.0 and mem == pytest.approx(4.0 * cpu)
    off = dataclasses.replace(_CFG, horizon=0.0)
    fc0 = _observe_trace(ArrivalForecaster(off), np.full(20, 5.0))
    assert fc0.horizon_demand() == (0.0, 0.0)


# ---------------------------------------------------------------- parity

_TRACE = Scenario(
    name="forecast-parity", workflows=("ligo",), arrival="poisson",
    arrival_params={"lam": 2.0, "bursts": 3, "interval": 40.0, "seed": 5},
    engine=EngineConfig().evolve(num_nodes=4), seed=1)


def _trace_of(result):
    return (result.metrics.alloc_trace, result.avg_total_duration,
            result.num_dispatches, result.num_waits)


@pytest.mark.parametrize("stream", [False, True])
def test_forecast_off_is_bit_for_bit_static(stream):
    """enabled=False must leave the engine untouched no matter what the
    other forecast knobs say — no forecaster, no telemetry, identical
    allocation trace offline and through the serving loop."""
    base = dataclasses.replace(_TRACE, stream=stream)
    r_default = run_scenario(base)
    exotic = ForecastConfig(enabled=False, history=8, window=2,
                            min_history=3, window_scale=9.0,
                            max_window=99.0, horizon=1e4, seed=42)
    r_off = run_scenario(dataclasses.replace(
        base, engine=base.engine.evolve(forecast=exotic)))
    assert _trace_of(r_off) == _trace_of(r_default)
    assert r_off.forecast_observations == 0
    assert r_off.forecast_predictions == 0
    assert r_off.forecast_ghost_rows == 0


def test_engine_fold_window_static_without_forecaster():
    eng = KubeAdaptor(_TRACE.engine)
    assert eng.fold_window() == _TRACE.engine.timing.batch_window


# ---------------------------------------------------------------- wiring

def test_adaptive_scaling_registered_with_forecast_capability():
    entry = ALLOCATORS.get("adaptive_scaling")
    assert entry.supports("forecast")
    assert entry.supports("lifecycle_window")
    assert not ALLOCATORS.get("aras").supports("forecast")


def test_adaptive_scaling_requires_enabled_forecast():
    cfg = EngineConfig().evolve(allocator="adaptive_scaling")
    with pytest.raises(ValueError, match="forecast"):
        cfg.validate()
    cfg.evolve(forecast=ForecastConfig(enabled=True)).validate()


def test_adaptive_scaling_beats_static_aras_on_ramping_trace():
    """The tentpole acceptance gate: on a contended ramping-Poisson
    stream, the forecast-driven allocator beats static-window ARAS on
    makespan AND dispatch efficiency (fewer fused dispatches for the
    same workload).  Served through the streaming loop, so the
    forecaster only ever sees past arrivals — honest prediction."""
    eng = EngineConfig().evolve(num_nodes=6)
    base = Scenario(
        name="forecast-acceptance", workflows=("ligo",),
        arrival="poisson",
        arrival_params={"lam": 3.0, "bursts": 8, "interval": 60.0,
                        "seed": 7, "ramp": 3.0},
        engine=eng, seed=3, stream=True)
    static = run_scenario(base)
    adaptive = run_scenario(dataclasses.replace(
        base, engine=eng.evolve(
            allocator="adaptive_scaling",
            forecast=ForecastConfig(enabled=True))))
    assert adaptive.num_workflows == static.num_workflows
    assert adaptive.avg_total_duration < static.avg_total_duration
    assert adaptive.num_dispatches < static.num_dispatches
    assert adaptive.mean_burst_width > static.mean_burst_width
    assert adaptive.forecast_predictions > 0


def test_grid_auto_enables_forecast_for_capable_allocators():
    cells = grid(_TRACE, allocators=("aras", "adaptive_scaling"),
                 arrivals=("poisson",))
    by_alloc = {c.engine.alloc.algorithm: c for c in cells}
    assert not by_alloc["aras"].engine.forecast.enabled
    assert by_alloc["adaptive_scaling"].engine.forecast.enabled
    for cell in cells:
        cell.validate()
    # An explicit forecast config on the base engine is kept as-is.
    pinned = dataclasses.replace(_TRACE, engine=_TRACE.engine.evolve(
        forecast=ForecastConfig(enabled=True, horizon=7.0)))
    cells = grid(pinned, allocators=("adaptive_scaling",),
                 arrivals=("poisson",))
    assert cells[0].engine.forecast.horizon == 7.0


def test_predictive_run_carries_forecast_telemetry():
    sc = dataclasses.replace(
        _TRACE,
        engine=_TRACE.engine.evolve(
            allocator="adaptive_scaling",
            forecast=ForecastConfig(enabled=True, min_history=4,
                                    window=3, history=16, hidden=8)),
        stream=True)
    r = run_scenario(sc)
    assert r.forecast_observations == r.num_workflows
    assert r.forecast_predictions > 0
    assert r.forecast_ghost_rows > 0
    assert r.mean_forecast_window >= 0.0
    data = r.to_dict()
    for key in ("forecast_observations", "forecast_predictions",
                "mean_forecast_window", "forecast_ghost_rows"):
        assert key in data
