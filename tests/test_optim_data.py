"""Optimizers (AdamW/Adafactor) and the data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.synthetic import SyntheticDataset
from repro.optim import Adafactor, AdamW, global_norm

pytestmark = pytest.mark.slow


def quad_problem():
    """f(w) = ||A w - b||²; optimizers must reduce it.

    ``b`` lies in the column space of A (b = A w*), so the effective
    rank-33 model (w collapses to a 32-vector through the ones/128
    contraction, plus the scalar v offset) can actually reach the
    reduction targets.  An unrealizable random b leaves ~50% of the
    loss as irreducible residual (64 equations, 33 dof) and no
    optimizer, however tuned, can pass — that was the seeded failure.
    """
    A = jax.random.normal(jax.random.key(0), (64, 32))
    b = A @ jax.random.normal(jax.random.key(1), (32,))
    w0 = {"w": jnp.zeros((32, 128)), "v": jnp.zeros((128,))}

    def loss(p):
        pred = A @ p["w"] @ jnp.ones((128,)) / 128 + p["v"].mean()
        return jnp.mean((pred - b) ** 2)

    return loss, w0


@pytest.mark.parametrize("opt,steps,target", [
    (AdamW(learning_rate=0.05), 60, 0.5),
    (AdamW(learning_rate=0.05, warmup_steps=10,
           total_steps=100), 60, 0.5),
    # Adafactor uses RMS-relative steps: smaller lr, more steps
    (Adafactor(learning_rate=0.05), 200, 0.7),
])
def test_optimizer_reduces_quadratic(opt, steps, target):
    loss, params = quad_problem()
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < target * l0


def test_adamw_clipping_bounds_update():
    opt = AdamW(learning_rate=1.0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.ones((8, 8))}
    state = opt.init(params)
    g = {"w": jnp.full((8, 8), 1e6)}  # exploding gradient
    new, _ = opt.update(g, state, params)
    # clipped: first-step Adam update magnitude ≤ lr regardless of g
    assert float(jnp.abs(new["w"] - params["w"]).max()) <= 1.1


def test_adafactor_state_is_factored():
    opt = Adafactor()
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4, 8))}
    st = opt.init(params)
    assert set(st.stats["big"]) == {"vr", "vc"}
    assert st.stats["big"]["vr"].shape == (256,)
    assert st.stats["big"]["vc"].shape == (512,)
    assert set(st.stats["small"]) == {"v"}  # too small to factor


def test_adafactor_memory_advantage():
    """Factored stats must be ≪ the Adam moment footprint."""
    opt_af, opt_adam = Adafactor(), AdamW()
    params = {"w": jnp.zeros((4096, 4096))}
    af = sum(x.size for x in jax.tree.leaves(opt_af.init(params).stats))
    adam = sum(x.size for x in jax.tree.leaves(opt_adam.init(params).mu)) \
        + sum(x.size for x in jax.tree.leaves(opt_adam.init(params).nu))
    assert af < adam / 1000


def test_lr_schedule_shape():
    opt = AdamW(learning_rate=1.0, warmup_steps=10, total_steps=110,
                lr_min_ratio=0.1)
    lrs = [float(opt.schedule(jnp.asarray(s))) for s in range(0, 111, 10)]
    assert lrs[0] < 0.2  # warming up
    assert lrs[1] == pytest.approx(1.0, abs=0.01)  # post-warmup peak
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)  # decayed to floor
    assert all(a >= b - 1e-6 for a, b in zip(lrs[1:], lrs[2:]))  # monotone


# ----------------------------------------------------------------- data

def test_batches_deterministic_per_step():
    cfg = get_smoke_config("llama3-8b")
    ds = SyntheticDataset(cfg, batch=4, seq=32, seed=7)
    a = ds.batch_at(13)
    b = ds.batch_at(13)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_batches_differ_across_steps_and_seeds():
    cfg = get_smoke_config("llama3-8b")
    ds = SyntheticDataset(cfg, batch=4, seq=32, seed=7)
    ds2 = SyntheticDataset(cfg, batch=4, seq=32, seed=8)
    assert not np.array_equal(np.asarray(ds.batch_at(0)["tokens"]),
                              np.asarray(ds.batch_at(1)["tokens"]))
    assert not np.array_equal(np.asarray(ds.batch_at(0)["tokens"]),
                              np.asarray(ds2.batch_at(0)["tokens"]))


def test_labels_are_next_tokens():
    cfg = get_smoke_config("llama3-8b")
    ds = SyntheticDataset(cfg, batch=16, seq=256, seed=0)
    b = ds.batch_at(0)
    # affine recurrence: labels mostly equal (31*t+7) % V; a pair breaks
    # when either side was corrupted: expect ≈ 0.95² = 0.90
    t = np.asarray(b["tokens"])
    l = np.asarray(b["labels"])
    frac = np.mean(l == (31 * t + 7) % cfg.vocab_size)
    assert 0.85 < frac < 0.95
    ds0 = SyntheticDataset(cfg, batch=4, seq=64, seed=0, noise=0.0)
    b0 = ds0.batch_at(0)
    assert np.all(np.asarray(b0["labels"]) ==
                  (31 * np.asarray(b0["tokens"]) + 7) % cfg.vocab_size)


def test_modality_stub_fields():
    for arch in ("llama-3.2-vision-90b", "whisper-base"):
        cfg = get_smoke_config(arch)
        ds = SyntheticDataset(cfg, batch=2, seq=8, seed=0)
        b = ds.batch_at(0)
        if cfg.is_vlm:
            assert b["vision_embeds"].shape == (
                2, cfg.num_vision_tokens, cfg.d_model)
        if cfg.is_encdec:
            assert b["frames"].shape == (
                2, cfg.num_audio_frames, cfg.d_model)
