"""Activation-sharding constraint rules (train vs serve modes)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel import act_sharding as act


def test_noop_without_context():
    """Outside a context every helper is the identity (CPU tests rely
    on this)."""
    x = jnp.ones((2, 4, 8))
    assert act.constrain_tokens(x) is x
    assert act.constrain_ff(x) is x
    assert act.constrain_logits(x) is x
    q = jnp.ones((2, 4, 4, 8))
    k = v = jnp.ones((2, 4, 2, 8))
    q2, k2, v2 = act.constrain_qkv(q, k, v)
    assert q2 is q and k2 is k and v2 is v

import pytest

pytestmark = pytest.mark.slow

_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.policy import ShardingPolicy
from repro.parallel import act_sharding as act

mesh = jax.make_mesh((4, 2), ("data", "model"))
pol = ShardingPolicy(mesh)


def spec_of(fn, shape, serve=False, **kw):
    with act.activation_sharding(pol, serve=serve):
        out = jax.jit(fn).lower(jax.ShapeDtypeStruct(shape, jnp.float32))
    return out  # we only need it to lower without error


# train mode: constraints must not break lowering and must shard batch
with act.activation_sharding(pol):
    x = jnp.ones((8, 4, 16))
    y = act.constrain_tokens(x)
    assert "data" in str(y.sharding.spec), y.sharding.spec
    h = jnp.ones((8, 4, 32))
    hh = act.constrain_ff(h)
    assert "model" in str(hh.sharding.spec)
    # divisibility fallback: 7 doesn't divide anything -> replicated dim
    odd = jnp.ones((7, 4, 16))
    oo = act.constrain_tokens(odd)
    assert oo.sharding.spec[0] is None
    assert any("7" in f for f in pol.fallbacks)

# serve mode: batch replicated, features over data
with act.activation_sharding(pol, serve=True):
    x = jnp.ones((8, 1, 16))
    y = act.constrain_tokens(x)
    assert y.sharding.spec[0] is None  # batch replicated
    assert y.sharding.spec[2] == "data"  # features over data

# qkv head fallback: 14 heads don't divide model=2? 14%2==0 -> heads shard
with act.activation_sharding(pol):
    q = jnp.ones((8, 16, 14, 8))
    k = v = jnp.ones((8, 16, 2, 8))
    q2, k2, v2 = act.constrain_qkv(q, k, v)
    assert q2.sharding.spec[2] == "model"
    # 3 heads don't divide model=2 -> seq sharding fallback
    q = jnp.ones((8, 16, 3, 8))
    k = v = jnp.ones((8, 16, 3, 8))
    q2, k2, v2 = act.constrain_qkv(q, k, v)
    assert q2.sharding.spec[1] == "model"  # query-seq over model
    assert q2.sharding.spec[2] is None

print("ACT_OK")
"""


def test_constraint_rules_on_mesh():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert "ACT_OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_moe_grouping_modes():
    """Training context groups by |dp|; serve context keeps groups=1;
    both match the no-context reference exactly."""
    import dataclasses
    from repro.models.config import MoEConfig

    cfg = ModelConfig(
        name="t", num_layers=1, d_model=32, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=128,
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=48,
                      capacity_factor=8.0))
    p = L.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    y_ref, _ = L.moe(p, cfg, x)
    # (grouped paths under a real mesh are exercised in test_dryrun;
    # here we check the no-context path is deterministic and matches the
    # einsum reference)
    cfg_e = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch_mode="einsum"))
    y_e, _ = L.moe(p, cfg_e, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_e),
                               atol=1e-4)
