"""End-to-end behaviour of the KubeAdaptor engine + ARAS (system tests).

Covers the paper's behavioural claims: topological execution, capacity
safety, ARAS-vs-FCFS dominance under contention, and OOM self-healing.
Randomized simulator-invariant properties (hypothesis) live in
``tests/property/test_system_props.py`` so this module collects on a
bare jax+pytest environment.
"""
import numpy as np
import pytest

from repro.engine import EngineConfig, KubeAdaptor, TimingConfig, \
    run_experiment
from repro.workflows import arrival
from repro.workflows.dags import cybershake, epigenomics, ligo, montage

pytestmark = pytest.mark.tier1

FAST = EngineConfig(timing=TimingConfig(
    pod_startup_delay=1.0, cleanup_delay=1.0, duration_multiplier=1.0))


# ------------------------------------------------------------ workflows

@pytest.mark.parametrize("builder,n", [(montage, 21), (epigenomics, 20),
                                       (cybershake, 22), (ligo, 23)])
def test_workflow_task_counts_match_paper(builder, n):
    wf = builder("w", np.random.default_rng(0))
    assert wf.num_tasks == n
    wf.topological_order()  # acyclic


def test_earliest_starts_respect_dependencies():
    wf = montage("w", np.random.default_rng(0))
    est = wf.earliest_starts(100.0)
    for a, b in wf.edges:
        assert est[b] >= est[a] + wf.tasks[a].duration - 1e-6


def test_arrival_patterns_match_paper_totals():
    assert arrival.total_workflows(arrival.constant()) == 30
    assert arrival.total_workflows(arrival.linear()) == 30
    assert arrival.total_workflows(arrival.pyramid()) == 34
    assert [n for _, n in arrival.pyramid()] == [2, 4, 6, 4, 2, 2, 4, 6, 4]


# --------------------------------------------------------------- engine

def test_single_workflow_executes_topologically():
    eng = KubeAdaptor(FAST)
    wf = montage("m0", np.random.default_rng(0))
    eng.submit(wf, 0.0)
    eng.run()
    run = eng.runs["m0"]
    assert run.complete
    # parents must finish before children start (via store records)
    for a, b in wf.edges:
        ra = eng.store.get(f"m0/{a}")
        rb = eng.store.get(f"m0/{b}")
        assert ra.flag and rb.flag
        if wf.tasks[b].cpu > 0 and wf.tasks[a].cpu > 0:
            assert rb.t_start >= ra.t_end - 1e-6, (a, b)


def test_all_workflows_complete_under_contention():
    m = run_experiment("ligo", [(0.0, 6)], "aras", seed=1, config=FAST)
    assert len(m.workflow_durations) == 6
    m = run_experiment("ligo", [(0.0, 6)], "fcfs", seed=1, config=FAST)
    assert len(m.workflow_durations) == 6


def test_aras_beats_fcfs_under_contention():
    """The paper's core claim, at test scale."""
    a = run_experiment("ligo", [(0.0, 8)], "aras", seed=0)
    f = run_experiment("ligo", [(0.0, 8)], "fcfs", seed=0)
    assert a.avg_workflow_duration < f.avg_workflow_duration
    assert a.makespan <= f.makespan * 1.02


def test_oom_selfheal_completes_workflows():
    """Paper 6.2.2: allocations below the runtime floor OOM, heal, finish."""
    kw = dict(mem=2600.0, min_mem=200.0, actual_min_mem=2000.0)
    m = run_experiment("montage", [(0.0, 10)], "aras", seed=0,
                       task_kwargs=kw)
    assert len(m.oom_events) > 0
    assert len(m.realloc_events) >= len(m.oom_events)
    assert len(m.workflow_durations) == 10  # everything still finished


def test_fcfs_never_scales_down():
    m = run_experiment("montage", [(0.0, 5)], "fcfs", seed=0)
    for _, _, cpu, mem, scen in m.alloc_trace:
        assert scen == "fcfs"
        assert cpu == 2000.0 and mem == 4000.0


def test_aras_scales_under_pressure():
    m = run_experiment("ligo", [(0.0, 8)], "aras", seed=0)
    scens = {s for *_, s in m.alloc_trace}
    assert scens - {"sufficient"}, "expected scaled allocations"
    assert any(c < 2000.0 for _, _, c, _, s in m.alloc_trace
               if s != "sufficient")
