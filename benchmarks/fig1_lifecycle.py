"""Fig. 1 reproduction: resource-scaling within a task's lifecycle.

Runs a burst of Montage workflows and prints the allocation trace —
which Alg.3 scenario fired per task and how far below the declared
request the scaled quota landed (the Fig. 1 'scaling down by Eq. (9)'
behaviour)."""
from __future__ import annotations

import time
from collections import Counter

from repro.engine import EngineConfig, run_experiment


def run(n_workflows: int = 5):
    m = run_experiment("montage", [(0.0, n_workflows)], "aras", seed=0,
                       config=EngineConfig())
    scenarios = Counter(s for *_, s in m.alloc_trace)
    scaled = [(t, key, cpu, mem) for t, key, cpu, mem, s in m.alloc_trace
              if s != "sufficient"]
    return m, scenarios, scaled


def main():
    t0 = time.time()
    m, scenarios, scaled = run()
    elapsed = time.time() - t0
    n_scaled = sum(v for k, v in scenarios.items() if k != "sufficient")
    print(f"fig1_lifecycle,{1e6*elapsed:.0f},"
          f"allocations={m.num_allocations}|scaled={n_scaled}|"
          f"scenarios={dict(scenarios)}")
    for t, key, cpu, mem in scaled[:8]:
        print(f"  t={t:7.1f}s {key:28s} cpu={cpu:7.1f}m mem={mem:7.1f}Mi "
              f"(declared 2000m/4000Mi)")
    return scenarios


if __name__ == "__main__":
    main()
