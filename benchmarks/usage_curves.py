"""Figs. 5-8 reproduction: CPU/memory usage-rate curves per arrival
pattern, ARAS vs baseline.  Emits peak and mean usage per curve and
writes the full time series to results/usage/<wf>_<pattern>_<alloc>.csv.
"""
from __future__ import annotations

import os
import time
from typing import Dict

import numpy as np

from repro.engine import EngineConfig, run_experiment
from repro.workflows.arrival import constant, linear, pyramid

OUT = "results/usage"


def run(workflow: str = "montage") -> Dict:
    os.makedirs(OUT, exist_ok=True)
    out: Dict = {}
    for pat_name, pat in [("constant", constant), ("linear", linear),
                          ("pyramid", pyramid)]:
        for alloc in ["aras", "fcfs"]:
            m = run_experiment(workflow, pat(), alloc, seed=0,
                               config=EngineConfig())
            series = np.asarray(m.usage_series)  # [n, 3] t, cpu, mem
            path = f"{OUT}/{workflow}_{pat_name}_{alloc}.csv"
            np.savetxt(path, series, delimiter=",",
                       header="t_s,cpu_usage,mem_usage", comments="")
            out[(pat_name, alloc)] = {
                "peak_cpu": float(series[:, 1].max()),
                "mean_cpu": m.avg_cpu_usage,
            }
    return out


def main():
    t0 = time.time()
    out = run()
    elapsed = time.time() - t0
    # paper: ARAS peak usage >= baseline peak for each pattern
    ok = all(out[(p, "aras")]["peak_cpu"] >= out[(p, "fcfs")]["peak_cpu"] - 0.02
             for p in ("constant", "linear", "pyramid"))
    peaks = {p: round(out[(p, "aras")]["peak_cpu"], 3)
             for p in ("constant", "linear", "pyramid")}
    print(f"usage_curves,{1e6*elapsed/6:.0f},"
          f"aras_peaks={peaks}|peak_dominance={'PASS' if ok else 'FAIL'}")
    return out


if __name__ == "__main__":
    main()
