"""Fig. 9 reproduction: resource-allocation failure and self-healing.

10 Montage workflows, constant burst; ``min_mem`` fine-tuned BELOW the
memory the Stress program actually touches (paper §6.2.2), so the scaled
allocation passes the Alg.1 acceptance gate yet the pod OOMKills at
runtime.  The engine must watch the OOMKilled event, delete the pod,
re-allocate with the learned floor and relaunch — every workflow still
completes.

Driven through the declarative :class:`repro.api.Scenario` surface (the
same spec ``examples/oom_selfheal.py`` runs), so the benchmark measures
exactly what a user-facing chaos scenario measures.
"""
from __future__ import annotations

import time
from typing import Dict

from repro.api import Scenario, run_scenario


def run() -> Dict:
    # Stress touches 2000 Mi at runtime; the user declared min_mem=200.
    # Under burst contention ARAS scales quotas below 2000+β -> OOMKilled.
    scenario = Scenario(
        name="fig9-oom",
        workflows=("montage",),
        arrival="constant",
        arrival_params={"y": 10, "bursts": 1},
        task_kwargs={"mem": 2600.0, "min_mem": 200.0,
                     "actual_min_mem": 2000.0},
        seed=0,
    )
    result = run_scenario(scenario)
    m = result.metrics
    return {
        "oom_events": len(m.oom_events),
        "reallocations": len(m.realloc_events),
        "first_oom_s": m.oom_events[0][0] if m.oom_events else None,
        "first_realloc_s": (m.realloc_events[0][0]
                            if m.realloc_events else None),
        "makespan_min": m.makespan / 60.0,
        "completed": result.num_workflows == 10,
    }


def main():
    t0 = time.time()
    r = run()
    elapsed = time.time() - t0
    ok = r["oom_events"] > 0 and r["reallocations"] >= r["oom_events"] \
        and r["completed"]
    print(f"fig9_oom,{1e6*elapsed:.0f},"
          f"oom={r['oom_events']}|realloc={r['reallocations']}|"
          f"healed={'PASS' if ok else 'FAIL'}")
    print(f"  first OOMKilled at {r['first_oom_s']:.1f}s, "
          f"first reallocation at {r['first_realloc_s']:.1f}s, "
          f"all 10 workflows completed in {r['makespan_min']:.1f} min")
    return r


if __name__ == "__main__":
    main()
