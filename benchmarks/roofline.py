"""Roofline analysis (§g): three terms per (arch × shape) from the dry-run.

Terms are **per chip** (XLA cost analysis reports the post-SPMD per-device
program; calibrated for scan-body undercounting by the dry-run):

    compute_s    = HLO_FLOPs_per_chip / 197e12         (bf16 peak, v5e)
    memory_s     = HLO_bytes_per_chip / 819e9          (HBM bandwidth)
    collective_s = collective_bytes_per_chip / 50e9    (per-link ICI)

``memory_s`` derives from the CPU backend's bytes-accessed, which counts
fusion-internal traffic a TPU would keep in registers/VMEM — treat it as
an upper bound (noted per row as the dominant-term tie-breaker).
MODEL_FLOPS uses 6·N·D (train) / 2·N·D (inference) with N = active
parameters; the ratio MODEL/HLO flags remat and redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.models.api import SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip (v5e)
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

DRYRUN_DIR = "results/dryrun"


def model_flops_per_chip(arch: str, shape_name: str, num_devices: int
                         ) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / num_devices


def load_cells(dryrun_dir: str = DRYRUN_DIR, mesh: str = "pod1"
               ) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(f"{dryrun_dir}/*__{mesh}.json")):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(cell: Dict) -> Optional[Dict]:
    if cell.get("status") != "ok":
        return None
    cal = cell.get("calibrated", {})
    flops = cal.get("flops", cell.get("flops", 0.0))
    nbytes = cal.get("bytes_accessed", cell.get("bytes_accessed", 0.0))
    coll = cal.get("collective_bytes_total",
                   cell.get("collective_bytes_total", 0.0))
    nd = cell.get("num_devices", 256)
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_chip(cell["arch"], cell["shape"], nd)
    mfu_bound = compute_s / max(terms.values()) if max(terms.values()) else 0
    return {
        "arch": cell["arch"], "shape": cell["shape"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": mfu_bound * (mf / flops if flops else 0.0),
        "hbm_gb_per_chip": cell.get("memory", {}).get(
            "temp_size_in_bytes", 0) / 1e9,
    }


SUGGESTIONS = {
    "compute": "raise useful-ratio (less remat recompute / fused attention)",
    "memory": "fuse or re-tile the dominant producer (Pallas kernel path)",
    "collective": "re-shard to cut the largest all-gather/all-reduce",
}


def _table(dryrun_dir: str, label: str):
    rows = [r for c in load_cells(dryrun_dir) if (r := roofline_row(c))]
    if not rows:
        return rows
    print(f"# {label} ({dryrun_dir})")
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_fraction")
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.4f},"
              f"{r['memory_s']:.4f},{r['collective_s']:.4f},"
              f"{r['dominant']},{r['useful_ratio']:.3f},"
              f"{r['roofline_fraction']:.3f}")
    n_dom = {}
    for r in rows:
        n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    print(f"#   cells={len(rows)} dominants={n_dom}")
    return rows


def main(dryrun_dir: str = DRYRUN_DIR):
    t0 = time.time()
    base = _table(dryrun_dir, "baseline (paper-faithful substrate)")
    opt = _table("results/dryrun_opt", "optimized (beyond-paper defaults)")
    rows = opt or base
    if not rows:
        print("roofline,0,no dry-run results yet — run repro.launch.dryrun")
        return []
    elapsed = time.time() - t0
    if base and opt:
        bmap = {(r["arch"], r["shape"]): r for r in base}
        gains = []
        for r in opt:
            b = bmap.get((r["arch"], r["shape"]))
            if b:
                mb = max(b["compute_s"], b["memory_s"], b["collective_s"])
                mo = max(r["compute_s"], r["memory_s"], r["collective_s"])
                if mo > 0:
                    gains.append(mb / mo)
        import numpy as np

        print(f"# dominant-term speedup optimized/baseline: "
              f"median {np.median(gains):.2f}x, max {max(gains):.1f}x")
    print(f"roofline,{1e6*elapsed:.0f},cells={len(rows)}")
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump({"baseline": base, "optimized": opt}, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
