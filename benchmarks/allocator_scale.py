"""Beyond-paper: control-plane scale-out of the ARAS algorithms.

The paper's Go loops are O(nodes × pods) per allocation; our JAX
implementation is one fused segment-sum + a branchless lattice, and the
evaluator vmaps whole request bursts.  This benchmark measures the
allocation-decision latency at 1k / 10k / 100k nodes (8 pods per node)
with 1024 concurrent task requests — the 1000+-node fleet scenario the
framework targets.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.discovery import _residuals
from repro.core.evaluation import EvalInputs, evaluate_batch


def bench(num_nodes: int, pods_per_node: int = 8, burst: int = 1024,
          iters: int = 20):
    rng = np.random.default_rng(0)
    P = num_nodes * pods_per_node
    alloc_cpu = jnp.full((num_nodes,), 8000.0, jnp.float32)
    alloc_mem = jnp.full((num_nodes,), 16000.0, jnp.float32)
    pod_node = jnp.asarray(rng.integers(0, num_nodes, P), jnp.int32)
    pod_cpu = jnp.asarray(rng.uniform(100, 1500, P), jnp.float32)
    pod_mem = jnp.asarray(rng.uniform(200, 3000, P), jnp.float32)
    pod_active = jnp.asarray(rng.random(P) < 0.8)

    task_cpu = jnp.asarray(rng.uniform(500, 4000, burst), jnp.float32)
    task_mem = jnp.asarray(rng.uniform(1000, 8000, burst), jnp.float32)
    req_cpu = task_cpu * 20
    req_mem = task_mem * 20

    @jax.jit
    def decide(ac, am, pn, pc, pm, pa, tc, tm, rc, rm):
        res_cpu, res_mem = _residuals(ac, am, pn, pc, pm, pa,
                                      num_nodes=num_nodes)
        total_cpu, total_mem = jnp.sum(res_cpu), jnp.sum(res_mem)
        i = jnp.argmax(res_cpu)
        return evaluate_batch(
            EvalInputs(tc, tm, rc, rm, total_cpu, total_mem,
                       res_cpu[i], res_mem[i]), 0.8)

    args = (alloc_cpu, alloc_mem, pod_node, pod_cpu, pod_mem, pod_active,
            task_cpu, task_mem, req_cpu, req_mem)
    jax.block_until_ready(decide(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = decide(*args)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / iters
    return dt


def main():
    for n in (1_000, 10_000, 100_000):
        dt = bench(n)
        print(f"allocator_scale_{n//1000}k,{1e6*dt:.0f},"
              f"nodes={n}|pods={8*n}|burst=1024|"
              f"us_per_decision={1e6*dt/1024:.2f}")


if __name__ == "__main__":
    main()
