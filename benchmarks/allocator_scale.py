"""Beyond-paper: control-plane scale-out of the ARAS algorithms.

The paper's Go loops are O(nodes × pods) per allocation; our JAX
implementation is one fused segment-sum + a branchless lattice, and the
engine decides an entire arrival burst in a single fused dispatch.

Five benchmarks:

* ``core``   — the evaluator kernel alone (discover + summarize +
  vmapped Alg. 3), as in the seed: raw device throughput.
* ``engine`` — the **engine-facing** allocation path: a KubeAdaptor at N
  nodes takes a burst of ready tasks through window building, batch
  assembly, the fused kernel, and pod binding.  Reported both ways:
  ``batched`` (one ``allocate_batch`` drain for the whole burst) vs
  ``per_task`` (the sequential reference loop, one dispatch per task) —
  the per-decision latency ratio is the win of making the burst, not the
  task, the allocation unit.
* ``stream`` (``--stream``) — the **serving loop** at scale: a Poisson
  arrival stream served by ``repro.serving.StreamEngine``, reporting
  sustained decisions/sec and p50/p99 per-decision latency, with the
  device-resident incremental state against the full re-pad baseline
  (``AllocatorConfig.incremental_state``).  In this regime each dispatch
  carries a handful of rows, so the O(nodes) per-dispatch re-staging is
  the dominant cost the incremental path removes — the
  ``p50_improvement`` column is that win.
* ``forecast`` (``--forecast``) — **predictive allocation**: the same
  ramping Poisson stream served twice, static-window ARAS vs the
  forecast-driven ``adaptive_scaling`` allocator
  (``EngineConfig.forecast`` / ``repro.forecast``) — the
  ``makespan_improvement`` / ``dispatch_reduction`` columns are the
  predictive win the scenario grid gates on.
* ``vertical`` (``--vertical``) — **vertical adaptivity (ARC-V)**: the
  same decaying usage-curve trace run twice, static engine vs the
  in-place resize controller (``EngineConfig.vertical`` /
  ``repro.vertical``) — the ``resizes`` / ``reclaimed`` columns are the
  over-provisioned capacity the controller hands back to admission.

Usage::

    PYTHONPATH=src python benchmarks/allocator_scale.py                 # full sweep
    PYTHONPATH=src python benchmarks/allocator_scale.py --nodes 1000    # one size
    PYTHONPATH=src python benchmarks/allocator_scale.py --nodes 1000 --burst 256
    PYTHONPATH=src python benchmarks/allocator_scale.py --clusters 4   # federated
    PYTHONPATH=src python benchmarks/allocator_scale.py --placement all
    PYTHONPATH=src python benchmarks/allocator_scale.py --stream --nodes 100000
    PYTHONPATH=src python benchmarks/allocator_scale.py --stream --chaos --nodes 64
    PYTHONPATH=src python benchmarks/allocator_scale.py --forecast --skip-core --skip-engine
    PYTHONPATH=src python benchmarks/allocator_scale.py --vertical --skip-core --skip-engine
    PYTHONPATH=src python benchmarks/allocator_scale.py --json BENCH_allocator.json

The engine benchmark takes a ``--clusters`` axis (federated multi-cluster
allocation, ``ClusterConfig.num_clusters``), a ``--placement`` axis
(any policy in the ``PLACEMENTS`` registry, or ``all``), and a
``--window`` axis (``TimingConfig.batch_window``: tasks arrive jittered
over ``--spread`` seconds and the windowed drain folds them back into
fused dispatches — the ``dispatches`` column shows how many); the
default full sweep records a {1, 2, 4}-cluster trajectory at the largest
engine size and an all-policies placement sweep at the smallest.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    PLACEMENTS,
    AllocatorConfig,
    ClusterConfig,
    EngineConfig,
    FaultConfig,
    TimingConfig,
)
from repro.core import EvalInputs, evaluate_batch, node_residuals
from repro.engine import KubeAdaptor
from repro.serving import StreamEngine
from repro.workflows import TaskSpec, WorkflowSpec


def bench_core(num_nodes: int, pods_per_node: int = 8, burst: int = 1024,
               iters: int = 20):
    """Evaluator-core latency (seed benchmark): one fused decide dispatch."""
    rng = np.random.default_rng(0)
    P = num_nodes * pods_per_node
    alloc_cpu = jnp.full((num_nodes,), 8000.0, jnp.float32)
    alloc_mem = jnp.full((num_nodes,), 16000.0, jnp.float32)
    pod_node = jnp.asarray(rng.integers(0, num_nodes, P), jnp.int32)
    pod_cpu = jnp.asarray(rng.uniform(100, 1500, P), jnp.float32)
    pod_mem = jnp.asarray(rng.uniform(200, 3000, P), jnp.float32)
    pod_active = jnp.asarray(rng.random(P) < 0.8)

    task_cpu = jnp.asarray(rng.uniform(500, 4000, burst), jnp.float32)
    task_mem = jnp.asarray(rng.uniform(1000, 8000, burst), jnp.float32)
    req_cpu = task_cpu * 20
    req_mem = task_mem * 20

    @jax.jit
    def decide(ac, am, pn, pc, pm, pa, tc, tm, rc, rm):
        res_cpu, res_mem = node_residuals(ac, am, pn, pc, pm, pa,
                                          num_nodes=num_nodes)
        total_cpu, total_mem = jnp.sum(res_cpu), jnp.sum(res_mem)
        i = jnp.argmax(res_cpu)
        return evaluate_batch(
            EvalInputs(tc, tm, rc, rm, total_cpu, total_mem,
                       res_cpu[i], res_mem[i]), 0.8)

    args = (alloc_cpu, alloc_mem, pod_node, pod_cpu, pod_mem, pod_active,
            task_cpu, task_mem, req_cpu, req_mem)
    jax.block_until_ready(decide(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = decide(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------- engine-facing

def _burst_spec(burst: int, rng: np.random.Generator,
                workflow_id: str = "w", offset: int = 0) -> WorkflowSpec:
    """One flat workflow of `burst` independent ready tasks."""
    tasks = {
        f"t{i}": TaskSpec(
            task_id=f"t{i}", image="bench",
            cpu=float(rng.uniform(500, 4000)),
            mem=float(rng.uniform(1000, 8000)),
            duration=float(rng.uniform(10, 20)),
            min_cpu=100.0, min_mem=200.0,
        )
        for i in range(offset, offset + burst)
    }
    return WorkflowSpec(workflow_id=workflow_id, tasks=tasks, edges=[])


def bench_engine(num_nodes: int, burst: int, batched: bool,
                 repeats: int = 3, clusters: int = 1,
                 placement: str = "worst_fit", window: float = 0.0,
                 spread: float = 0.0):
    """Engine-facing burst latency: inject `burst` ready tasks, time the
    allocation drain (window build → batch assembly → fused dispatch →
    bind) — everything between the READY events and the running pods.
    ``clusters > 1`` runs the federated multi-cluster layout
    (repro.cluster.federation): cluster-major tiles, per-shard totals;
    ``placement`` selects any registered placement policy.  ``spread``
    jitters the arrivals uniformly over that many seconds (one
    single-task workflow each) and ``window`` is the drain's
    ``batch_window`` folding them back into fused dispatches.

    Returns ``(seconds, num_dispatches)`` for the winning repeat."""
    rng = np.random.default_rng(0)
    if spread > 0.0:
        specs = [(_burst_spec(1, rng, workflow_id=f"w{i}", offset=i),
                  float(t))
                 for i, t in enumerate(np.sort(rng.uniform(0, spread, burst)))]
    else:
        specs = [(_burst_spec(burst, rng), 0.0)]
    cfg = EngineConfig(
        cluster=ClusterConfig(num_nodes=num_nodes, node_cpu=8000.0,
                              node_mem=16000.0, num_clusters=clusters),
        alloc=AllocatorConfig(batch_allocation=batched,
                              placement=placement),
        timing=TimingConfig(pod_startup_delay=1.0, cleanup_delay=1.0,
                            duration_multiplier=1.0, batch_window=window),
        invariant_checks=False,
    )

    def one_run():
        eng = KubeAdaptor(cfg)
        if spread > 0.0:
            # Jittered arrivals: injection interleaves with the windowed
            # drain by design, so it is part of the measured path.
            for spec, t in specs:
                eng.submit(spec, t)
        else:
            # Lockstep burst: register records outside the timed region
            # (the pre-windowed methodology — keeps the headline
            # spread=0 rows comparable across PRs).
            eng._inject(specs[0][0])
        t0 = time.perf_counter()
        # Drive events until the whole burst is placed (completions start
        # no earlier than startup_delay + min duration ≈ 11 s, so any
        # spread below that keeps this a pure allocation-path measure).
        while eng.queue and eng.metrics.num_allocations < burst:
            eng.step()
        dt = time.perf_counter() - t0
        assert eng.metrics.num_allocations == burst, (
            f"burst not fully placed: {eng.metrics.num_allocations}/{burst}"
        )
        return dt, eng.metrics.num_dispatches

    one_run()  # compile warmup
    return min(one_run() for _ in range(repeats))


def report_engine(num_nodes: int, burst: int, repeats: int,
                  clusters: int = 1, placement: str = "worst_fit",
                  window: float = 0.0, spread: float = 0.0) -> dict:
    dt_b, disp_b = bench_engine(num_nodes, burst, batched=True,
                                repeats=repeats, clusters=clusters,
                                placement=placement, window=window,
                                spread=spread)
    dt_p, _ = bench_engine(num_nodes, burst, batched=False,
                           repeats=repeats, clusters=clusters,
                           placement=placement, window=window,
                           spread=spread)
    speedup = dt_p / dt_b
    print(
        f"engine_scale_{num_nodes}n_{clusters}c_{placement},"
        f"batched={1e6*dt_b/burst:.2f}us/decision,"
        f"per_task={1e6*dt_p/burst:.2f}us/decision,"
        f"nodes={num_nodes}|burst={burst}|clusters={clusters}|"
        f"placement={placement}|window={window}|dispatches={disp_b}|"
        f"speedup={speedup:.1f}x"
    )
    return {
        "nodes": num_nodes,
        "burst": burst,
        "clusters": clusters,
        "placement": placement,
        "window": window,
        "spread": spread,
        "num_dispatches": disp_b,
        "batched_us_per_decision": round(1e6 * dt_b / burst, 3),
        "per_task_us_per_decision": round(1e6 * dt_p / burst, 3),
        "speedup": round(speedup, 2),
    }


# --------------------------------------------------------------- streaming

def _stream_arrivals(count: int, mean_gap: float = 1.0):
    """Poisson arrival stream of single-task workflows, time-sorted."""
    rng = np.random.default_rng(0)
    out, t = [], 0.0
    for i in range(count):
        t += float(rng.exponential(mean_gap))
        out.append((t, _burst_spec(1, rng, workflow_id=f"s{i}", offset=i)))
    return out


def bench_stream(num_nodes: int, arrivals: int, repeats: int = 3,
                 window: float = 0.0, clusters: int = 1,
                 incremental: bool = True, chaos: bool = False):
    """Serve a Poisson stream; returns the best repeat's StreamStats.

    ``incremental`` toggles the device-resident state against the full
    re-pad baseline — same decisions bit-for-bit, different per-dispatch
    cost.  ``chaos`` crashes an eighth of the cluster (seed-chosen, min
    2 nodes) at sim-time 10 s — mid-stream — so the measured path
    includes cordon, drain and HEAL re-admission traffic.
    """
    faults = FaultConfig(
        schedule="node_crash",
        params={"at": 10.0, "nodes": max(2, num_nodes // 8)},
        seed=0) if chaos else FaultConfig()
    cfg = EngineConfig(
        cluster=ClusterConfig(num_nodes=num_nodes, node_cpu=8000.0,
                              node_mem=16000.0, num_clusters=clusters),
        alloc=AllocatorConfig(incremental_state=incremental),
        timing=TimingConfig(pod_startup_delay=1.0, cleanup_delay=1.0,
                            duration_multiplier=1.0, batch_window=window),
        faults=faults,
        invariant_checks=False,
    )
    best = None
    for i in range(repeats + 1):  # extra first run = compile warmup
        stats = StreamEngine(KubeAdaptor(cfg),
                             _stream_arrivals(arrivals)).serve()
        if i and (best is None or stats.p50_latency_s < best.p50_latency_s):
            best = stats
    return best


def report_stream(num_nodes: int, arrivals: int, repeats: int,
                  window: float = 0.0, clusters: int = 1,
                  chaos: bool = False) -> dict:
    inc = bench_stream(num_nodes, arrivals, repeats, window=window,
                       clusters=clusters, incremental=True, chaos=chaos)
    rep = bench_stream(num_nodes, arrivals, repeats, window=window,
                       clusters=clusters, incremental=False, chaos=chaos)
    improvement = (rep.p50_latency_s / inc.p50_latency_s
                   if inc.p50_latency_s > 0 else float("inf"))
    print(
        f"stream_scale_{num_nodes}n_{clusters}c{'_chaos' if chaos else ''},"
        f"incremental={1e6*inc.p50_latency_s:.0f}us_p50/"
        f"{1e6*inc.p99_latency_s:.0f}us_p99/"
        f"{inc.decisions_per_sec:.0f}dps,"
        f"repad={1e6*rep.p50_latency_s:.0f}us_p50/"
        f"{1e6*rep.p99_latency_s:.0f}us_p99/"
        f"{rep.decisions_per_sec:.0f}dps,"
        f"nodes={num_nodes}|arrivals={arrivals}|window={window}|"
        f"p50_improvement={improvement:.1f}x"
    )

    def flat(stats):
        return {
            "decisions": stats.decisions,
            "dispatches": stats.dispatches,
            "decisions_per_sec": round(stats.decisions_per_sec, 1),
            "p50_latency_us": round(1e6 * stats.p50_latency_s, 1),
            "p99_latency_us": round(1e6 * stats.p99_latency_s, 1),
            "overlapped_ingests": stats.overlapped_ingests,
        }

    out = {
        "nodes": num_nodes,
        "arrivals": arrivals,
        "clusters": clusters,
        "window": window,
        "chaos": chaos,
        "incremental": flat(inc),
        "repad": flat(rep),
        "p50_improvement": round(improvement, 2),
    }
    if chaos:
        out["displaced"] = inc.metrics.num_displaced
        out["recovered"] = inc.metrics.num_recovered
    return out


# --------------------------------------------------------------- forecast

def report_forecast(num_nodes: int, seed: int = 7) -> dict:
    """Predictive allocation vs static ARAS on a ramping Poisson stream.

    Both runs serve the same contended trace through the streaming loop
    (honest prediction: the forecaster only ever sees past arrivals).
    The adaptive run uses the ``adaptive_scaling`` allocator with the
    default ``ForecastConfig`` — the makespan/dispatch deltas are the
    predictive-allocation win the scenario grid gates on.
    """
    from repro.api import ForecastConfig, Scenario, run_scenario

    eng = EngineConfig(
        cluster=ClusterConfig(num_nodes=num_nodes),
        invariant_checks=False,
    )
    base = Scenario(
        name=f"forecast-bench-{num_nodes}n", workflows=("ligo",),
        arrival="poisson",
        arrival_params={"lam": 3.0, "bursts": 8, "interval": 60.0,
                        "seed": seed, "ramp": 3.0},
        engine=eng, seed=3, stream=True)
    r_static = run_scenario(base)
    import dataclasses as _dc
    r_adaptive = run_scenario(_dc.replace(base, engine=eng.evolve(
        allocator="adaptive_scaling",
        forecast=ForecastConfig(enabled=True))))

    def flat(r):
        return {
            "makespan": round(r.avg_total_duration, 2),
            "num_dispatches": r.num_dispatches,
            "mean_burst_width": round(r.mean_burst_width, 2),
            "num_waits": r.num_waits,
            "forecast_predictions": r.forecast_predictions,
            "mean_forecast_window": round(r.mean_forecast_window, 3),
            "forecast_ghost_rows": r.forecast_ghost_rows,
        }

    mk_gain = (r_static.avg_total_duration / r_adaptive.avg_total_duration
               if r_adaptive.avg_total_duration > 0 else float("inf"))
    disp_gain = (r_static.num_dispatches / r_adaptive.num_dispatches
                 if r_adaptive.num_dispatches else float("inf"))
    print(
        f"forecast_scale_{num_nodes}n,"
        f"static={r_static.avg_total_duration:.1f}mk/"
        f"{r_static.num_dispatches}disp,"
        f"adaptive={r_adaptive.avg_total_duration:.1f}mk/"
        f"{r_adaptive.num_dispatches}disp,"
        f"nodes={num_nodes}|makespan_improvement={mk_gain:.3f}x|"
        f"dispatch_reduction={disp_gain:.3f}x"
    )
    return {
        "nodes": num_nodes,
        "arrival": dict(base.arrival_params),
        "static": flat(r_static),
        "adaptive": flat(r_adaptive),
        "makespan_improvement": round(mk_gain, 4),
        "dispatch_reduction": round(disp_gain, 4),
    }


# --------------------------------------------------------------- vertical

def report_vertical(num_nodes: int, seed: int = 3) -> dict:
    """In-place resize (ARC-V) vs the static engine on a decaying ramp.

    Both runs execute the same seeded usage-curve trace — actual
    consumption decays from 90% to 20% of the admitted quota.  The
    vertical run's controller shrinks the over-provisioned Running pods
    and the pending queue admits against the reclaimed capacity; the
    static run holds every quota until completion.  ``resizes`` and
    ``reclaimed`` are the telemetry the CI vertical smoke gates on.
    """
    import dataclasses as _dc

    from repro.api import Scenario, run_scenario

    eng = EngineConfig(
        cluster=ClusterConfig(num_nodes=num_nodes),
        invariant_checks=False,
    )
    base = Scenario(
        name=f"vertical-bench-{num_nodes}n", workflows=("montage",),
        arrival="constant",
        arrival_params={"y": 4, "bursts": 2, "interval": 30.0},
        usage_curves={"montage": {"curve": "ramp",
                                  "params": {"start": 0.9, "end": 0.2}}},
        engine=eng, seed=seed)
    r_static = run_scenario(base)
    r_vert = run_scenario(_dc.replace(base, engine=eng.evolve(
        vertical=True, resize_interval=15.0)))

    def flat(r):
        return {
            "makespan": round(r.avg_total_duration, 2),
            "num_dispatches": r.num_dispatches,
            "num_waits": r.num_waits,
            "resizes": r.num_resizes,
            "shrinks": r.num_shrinks,
            "grows": r.num_grows,
            "reclaimed": {
                "cpu_seconds": round(r.reclaimed_cpu_seconds, 1),
                "mem_seconds": round(r.reclaimed_mem_seconds, 1),
            },
        }

    print(
        f"vertical_scale_{num_nodes}n,"
        f"static={r_static.avg_total_duration:.1f}mk,"
        f"vertical={r_vert.avg_total_duration:.1f}mk,"
        f"nodes={num_nodes}|resizes={r_vert.num_resizes}|"
        f"reclaimed_cpu_s={r_vert.reclaimed_cpu_seconds:.0f}|"
        f"reclaimed_mem_s={r_vert.reclaimed_mem_seconds:.0f}"
    )
    return {
        "nodes": num_nodes,
        "curve": dict(base.usage_curves["montage"]["params"]),
        "static": flat(r_static),
        "vertical": flat(r_vert),
        "resizes": r_vert.num_resizes,
        "reclaimed": flat(r_vert)["reclaimed"],
    }


def report_core(num_nodes: int, burst: int) -> dict:
    dt = bench_core(num_nodes, burst=burst)
    print(f"allocator_scale_{num_nodes}n,{1e6*dt:.0f},"
          f"nodes={num_nodes}|pods={8*num_nodes}|burst={burst}|"
          f"us_per_decision={1e6*dt/burst:.2f}")
    return {
        "nodes": num_nodes,
        "pods": 8 * num_nodes,
        "burst": burst,
        "dispatch_us": round(1e6 * dt, 1),
        "us_per_decision": round(1e6 * dt / burst, 3),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=None,
                    help="single cluster size (default: 1k/10k/100k sweep)")
    ap.add_argument("--burst", type=int, default=1024,
                    help="ready tasks per arrival burst")
    ap.add_argument("--clusters", type=int, default=None,
                    help="federated cluster count for the engine benchmark "
                         "(default: 1, plus a {1,2,4} sweep at the largest "
                         "engine size when no --nodes is given)")
    ap.add_argument("--placement", default=None,
                    choices=list(PLACEMENTS.names()) + ["all"],
                    help="placement policy for the engine benchmark "
                         "(default: worst_fit, plus an all-policies sweep "
                         "at the smallest engine size when no --nodes is "
                         "given; 'all' sweeps every registered policy)")
    ap.add_argument("--window", type=float, default=0.0,
                    help="TimingConfig.batch_window for the engine "
                         "benchmark: fold arrivals within this many "
                         "seconds of the head event into one fused "
                         "dispatch (default 0 = same-timestamp only)")
    ap.add_argument("--spread", type=float, default=None,
                    help="jitter the burst's arrivals uniformly over this "
                         "many seconds (single-task workflows; default: "
                         "4x --window capped at 8, 0 = one lockstep "
                         "burst; keep it under ~10 s so completions stay "
                         "out of the timed region)")
    ap.add_argument("--forecast", action="store_true",
                    help="also run the predictive-allocation benchmark: "
                         "static-window ARAS vs the forecast-driven "
                         "adaptive_scaling allocator on the same ramped "
                         "Poisson stream (makespan + dispatch deltas)")
    ap.add_argument("--stream", action="store_true",
                    help="also run the serving-loop benchmark: a Poisson "
                         "arrival stream through repro.serving.StreamEngine, "
                         "incremental device-resident state vs the full "
                         "re-pad baseline (decisions/sec + p50/p99 latency)")
    ap.add_argument("--stream-arrivals", type=int, default=64,
                    help="arrivals in the served stream (default 64)")
    ap.add_argument("--chaos", action="store_true",
                    help="crash an eighth of the cluster at sim-time 10 s "
                         "mid-stream (repro.chaos node_crash): the "
                         "measured path then includes cordon, drain and "
                         "HEAL re-admission traffic")
    ap.add_argument("--vertical", action="store_true",
                    help="run the vertical-adaptivity comparison: static "
                         "engine vs the ARC-V resize controller on a "
                         "decaying usage-curve trace")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--skip-engine", action="store_true")
    ap.add_argument("--skip-core", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write machine-readable results to PATH")
    args = ap.parse_args()
    if args.nodes is not None and args.nodes <= 0:
        ap.error("--nodes must be positive")
    if args.burst <= 0:
        ap.error("--burst must be positive")
    if args.clusters is not None and args.clusters <= 0:
        ap.error("--clusters must be positive")
    if args.window < 0:
        ap.error("--window must be >= 0")
    if args.spread is None:
        # Cap the derived default below the ~11 s first completion so the
        # timed region stays allocation-only unless the user opts out.
        args.spread = min(4.0 * args.window, 8.0)
    if args.spread < 0:
        ap.error("--spread must be >= 0")

    core_sizes = ([args.nodes] if args.nodes is not None
                  else [1_000, 10_000, 100_000, 1_000_000])
    engine_sizes = [args.nodes] if args.nodes is not None else [1_000, 10_000]
    stream_sizes = ([args.nodes] if args.nodes is not None
                    else [100_000, 1_000_000])
    results = {
        "benchmark": "allocator_scale",
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "burst": args.burst,
        "core": [],
        "engine": [],
        "stream": [],
        "forecast": [],
        "vertical": [],
    }
    if not args.skip_core:
        for n in core_sizes:
            results["core"].append(report_core(n, args.burst))
    if not args.skip_engine:
        for n in engine_sizes:
            if args.clusters is not None:
                cluster_axis = [args.clusters]
            elif args.nodes is None and n == engine_sizes[-1]:
                # The federation trajectory rides the largest sweep size.
                cluster_axis = [1, 2, 4]
            else:
                cluster_axis = [1]
            if args.placement == "all":
                placement_axis = list(PLACEMENTS.names())
            elif args.placement is not None:
                placement_axis = [args.placement]
            elif args.nodes is None and n == engine_sizes[0]:
                # The placement trajectory rides the smallest sweep size.
                placement_axis = list(PLACEMENTS.names())
            else:
                placement_axis = ["worst_fit"]
            for c in cluster_axis:
                for pol in placement_axis:
                    results["engine"].append(
                        report_engine(n, args.burst, args.repeats,
                                      clusters=c, placement=pol,
                                      window=args.window,
                                      spread=args.spread))
    if args.stream:
        for n in stream_sizes:
            results["stream"].append(
                report_stream(n, args.stream_arrivals, args.repeats,
                              window=args.window,
                              clusters=args.clusters or 1,
                              chaos=args.chaos))
    if args.forecast:
        # Contended small clusters are where prediction moves the
        # needle; the axis rides --nodes when given, else a 6-node run.
        results["forecast"].append(report_forecast(args.nodes or 6))
    if args.vertical:
        # Contention is what makes reclaimed capacity visible; the axis
        # rides --nodes when given, else a 6-node run.
        results["vertical"].append(report_vertical(args.nodes or 6))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(results, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
