"""Table 2 reproduction: 4 workflows × 3 arrival patterns × {ARAS, FCFS}.

Emits the paper's three metrics per cell and the ARAS-vs-baseline savings,
then validates the savings against the paper's reported bands:
total-duration saving 9.8–40.92 %, per-workflow saving 26.4–79.86 %,
utilization gain +1–16 pp.

Note on the usage metric: the paper's §6.2.1 comparisons quote the
curves' *maximum* ("features a maximum value of 35% for our ARAS, 4%
higher than the baseline"), so the usage-gain check compares PEAK
utilization; the time-averaged mean is also reported (in an idle-free
simulator the full-request baseline holds more quota on average — see
EXPERIMENTS §Repro).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.engine import EngineConfig, run_experiment
from repro.workflows.arrival import constant, linear, pyramid

WORKFLOWS = ["montage", "epigenomics", "cybershake", "ligo"]
PATTERNS = {"constant": constant, "linear": linear, "pyramid": pyramid}

PAPER_BANDS = {
    "total_saving_pct": (9.8, 40.92),
    "wf_saving_pct": (26.4, 79.86),
    "usage_gain_pp": (1.0, 16.0),
}
# tolerance beyond the paper band edges accepted for the reproduction:
# the validated claims are (1) ARAS strictly dominates the baseline on
# every metric/cell and (2) savings land in/near the paper's bands with
# the paper's ordering across workflow topologies; absolute band edges
# shift with testbed constants we cannot observe (kubelet/image-pull
# jitter, engine serialization) — see EXPERIMENTS §Repro.
BAND_SLACK = 0.65


def run(reps: int = 1, seed0: int = 0, verbose: bool = True
        ) -> List[Dict]:
    rows: List[Dict] = []
    for wf in WORKFLOWS:
        for pat_name, pat in PATTERNS.items():
            cell: Dict = {"workflow": wf, "pattern": pat_name}
            for alloc in ["aras", "fcfs"]:
                makespans, wfdurs, cpu_us, mem_us, peaks = [], [], [], [], []
                for r in range(reps):
                    m = run_experiment(wf, pat(), alloc, seed=seed0 + r,
                                       config=EngineConfig())
                    makespans.append(m.makespan / 60.0)
                    wfdurs.append(m.avg_workflow_duration / 60.0)
                    cpu_us.append(m.avg_cpu_usage)
                    mem_us.append(m.avg_mem_usage)
                    series = np.asarray(m.usage_series)
                    peaks.append(float(series[:, 1].max()))
                cell[f"{alloc}_total_min"] = float(np.mean(makespans))
                cell[f"{alloc}_total_std"] = float(np.std(makespans))
                cell[f"{alloc}_wf_min"] = float(np.mean(wfdurs))
                cell[f"{alloc}_wf_std"] = float(np.std(wfdurs))
                cell[f"{alloc}_cpu"] = float(np.mean(cpu_us))
                cell[f"{alloc}_mem"] = float(np.mean(mem_us))
                cell[f"{alloc}_peak"] = float(np.mean(peaks))
            cell["total_saving_pct"] = 100 * (
                1 - cell["aras_total_min"] / cell["fcfs_total_min"])
            cell["wf_saving_pct"] = 100 * (
                1 - cell["aras_wf_min"] / cell["fcfs_wf_min"])
            # paper §6.2.1 quotes PEAK usage ("maximum value ... higher
            # than the baseline")
            cell["usage_gain_pp"] = 100 * (
                cell["aras_peak"] - cell["fcfs_peak"])
            rows.append(cell)
            if verbose:
                print(f"  {wf:12s} {pat_name:9s} "
                      f"total {cell['aras_total_min']:6.2f}/"
                      f"{cell['fcfs_total_min']:6.2f} min "
                      f"(-{cell['total_saving_pct']:5.1f}%)  "
                      f"wf {cell['aras_wf_min']:5.2f}/"
                      f"{cell['fcfs_wf_min']:5.2f} min "
                      f"(-{cell['wf_saving_pct']:5.1f}%)  "
                      f"peak-usage {cell['usage_gain_pp']:+4.1f}pp", flush=True)
    return rows


def validate(rows: List[Dict]) -> Dict[str, bool]:
    """ARAS must beat FCFS everywhere; mean savings must sit inside the
    paper's reported min/max bands (with slack for testbed constants)."""
    checks: Dict[str, bool] = {}
    checks["aras_always_faster_total"] = all(
        r["total_saving_pct"] > 0 for r in rows)
    checks["aras_always_faster_wf"] = all(
        r["wf_saving_pct"] > 0 for r in rows)
    checks["aras_usage_never_lower"] = all(
        r["usage_gain_pp"] > -1.0 for r in rows)
    for key, (lo, hi) in PAPER_BANDS.items():
        vals = [r[key] for r in rows]
        checks[f"{key}_within_band"] = (
            min(vals) >= lo * (1 - BAND_SLACK) - 1.0
            and max(vals) <= hi * (1 + BAND_SLACK) + 1.0)
    return checks


def main(reps: int = 1):
    t0 = time.time()
    rows = run(reps=reps)
    checks = validate(rows)
    elapsed = time.time() - t0
    mean_total = float(np.mean([r["total_saving_pct"] for r in rows]))
    mean_wf = float(np.mean([r["wf_saving_pct"] for r in rows]))
    print(f"table2,{1e6*elapsed/len(rows):.0f},"
          f"total_saving={mean_total:.1f}%|wf_saving={mean_wf:.1f}%|"
          f"checks={'PASS' if all(checks.values()) else 'FAIL'}")
    for k, v in checks.items():
        print(f"  check {k}: {'ok' if v else 'FAIL'}")
    return rows, checks


if __name__ == "__main__":
    main()
