"""Benchmark harness: one entry per paper table/figure + roofline.

Each benchmark prints ``name,us_per_call,derived`` CSV lines.
``python -m benchmarks.run [--quick]``.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the full Table-2 matrix (CI mode)")
    args = ap.parse_args()

    from benchmarks import (
        allocator_scale,
        fig1_lifecycle,
        fig9_oom,
        roofline,
        table2,
        usage_curves,
    )

    benches = [
        ("fig1_lifecycle", fig1_lifecycle.main),
        ("fig9_oom", fig9_oom.main),
        ("allocator_scale", allocator_scale.main),
        ("usage_curves", usage_curves.main),
        ("roofline", roofline.main),
    ]
    if not args.quick:
        benches.insert(0, ("table2", table2.main))

    failures = []
    for name, fn in benches:
        print(f"== {name} ==", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED benches: {failures}")
        sys.exit(1)
    print("all benches complete")


if __name__ == "__main__":
    main()
